"""Architecture + shape config registry.

Importing this package registers all assigned architectures.
"""
from repro.configs.base import (
    FLConfig,
    ModelConfig,
    SHAPES,
    ShapeConfig,
    get_arch,
    list_arches,
    reduced,
    register_arch,
)

# Importing registers each arch (side effect).
from repro.configs import (  # noqa: F401
    recurrentgemma_2b,
    gemma2_2b,
    paligemma_3b,
    llama4_maverick_400b_a17b,
    mixtral_8x7b,
    whisper_small,
    h2o_danube_3_4b,
    rwkv6_1_6b,
    mistral_large_123b,
    granite_3_8b,
)

ALL_ARCH_MODULES = (
    recurrentgemma_2b,
    gemma2_2b,
    paligemma_3b,
    llama4_maverick_400b_a17b,
    mixtral_8x7b,
    whisper_small,
    h2o_danube_3_4b,
    rwkv6_1_6b,
    mistral_large_123b,
    granite_3_8b,
)

ARCH_IDS = tuple(m.CONFIG.name for m in ALL_ARCH_MODULES)

# long_500k applicability (DESIGN.md §4.1): pure full-attention archs and
# the bounded-context enc-dec are skipped.
LONG_CONTEXT_SKIP = frozenset({
    "mistral-large-123b",
    "granite-3-8b",
    "paligemma-3b",
    "whisper-small",
})


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k" and arch in LONG_CONTEXT_SKIP:
        return False
    return True

__all__ = [
    "FLConfig", "ModelConfig", "SHAPES", "ShapeConfig", "get_arch",
    "list_arches", "reduced", "register_arch", "ARCH_IDS",
    "LONG_CONTEXT_SKIP", "shape_applicable",
]

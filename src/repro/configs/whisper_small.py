"""Whisper-small [arXiv:2212.04356] — encoder-decoder; mel+conv frontend
is a STUB (input_specs provides 1500 precomputed frame embeddings).
12L enc + 12L dec, d_model=768 12H (MHA kv=12) d_ff=3072 vocab=51865."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    enc_layers=12,
    enc_frames=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    block_pattern=("A",),
    ffn_act="gelu",
    rope_theta=0.0,        # learned absolute positions
    tie_embeddings=True,
    fl_strategy="two_phase",
    citation="arXiv:2212.04356",
))

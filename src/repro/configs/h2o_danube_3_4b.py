"""H2O-Danube3-4B [arXiv:2401.16818] — llama+mistral mix, sliding-window
attention. 24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    block_pattern=("L",),
    window=4096,
    ffn_act="swiglu",
    fl_strategy="two_phase",
    citation="arXiv:2401.16818",
))

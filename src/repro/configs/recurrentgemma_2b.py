"""RecurrentGemma-2B [arXiv:2402.19427] — Griffin-style hybrid:
RG-LRU recurrent blocks + local attention in a 1:2 ratio
(pattern: recurrent, recurrent, local-attn). 26L d_model=2560 10H
(GQA kv=1, MQA) d_ff=7680 vocab=256000, window 2048."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("R", "R", "L"),
    window=2048,
    rg_lru_dim=2560,
    ffn_act="geglu",
    emb_scale=True,
    logit_softcap=30.0,
    fl_strategy="two_phase",
    citation="arXiv:2402.19427",
))

"""Granite-3.0 8B [hf:ibm-granite/granite-3.0-2b-base family] — dense GQA.
40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
long_500k skipped (full attention)."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    block_pattern=("A",),
    ffn_act="swiglu",
    fl_strategy="two_phase",
    citation="hf:ibm-granite/granite-3.0-2b-base",
))

"""Mixtral-8x7B [arXiv:2401.04088] — MoE 8 experts top-2, sliding-window
attention. 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
FSDP + fused FL strategy (47B params)."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=("L",),
    window=4096,
    n_experts=8,
    top_k=2,
    ffn_act="swiglu",
    rope_theta=1000000.0,
    fl_strategy="fused",
    fsdp=True,
    citation="arXiv:2401.04088",
))

"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892] — attention-free SSM with
data-dependent decay time-mix. 24L d_model=2048 d_ff=7168 vocab=65536.
Decode state is O(1); long_500k natural fit."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    n_heads=32,               # time-mix heads (head_dim 64)
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    block_pattern=("W",),
    ffn_act="gelu",           # rwkv channel-mix (squared relu approx by gelu path)
    rope_theta=0.0,
    tie_embeddings=False,
    fl_strategy="two_phase",
    citation="arXiv:2404.05892",
))

"""Gemma-2 2B [arXiv:2408.00118] — dense, alternating local/global
attention, attention + final-logit soft-capping, GQA kv=4.
26L d_model=2304 8H d_ff=9216 vocab=256000, window 4096."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    block_pattern=("L", "A"),
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    ffn_act="geglu",
    emb_scale=True,
    fl_strategy="two_phase",
    citation="arXiv:2408.00118",
))

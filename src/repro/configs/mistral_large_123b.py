"""Mistral-Large-Instruct-2407 123B [hf:mistralai/Mistral-Large-Instruct-2407]
— dense full attention. 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768. FSDP + fused FL strategy. long_500k skipped (full attention)."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    block_pattern=("A",),
    ffn_act="swiglu",
    rope_theta=1000000.0,
    fl_strategy="fused",
    fsdp=True,
    citation="hf:mistralai/Mistral-Large-Instruct-2407",
))

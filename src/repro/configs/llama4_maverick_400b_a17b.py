"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family]
— MoE 128 experts top-1, early-fusion, iRoPE-style chunked attention with
periodic global (NoPE) layers. 48L d_model=5120 40H (GQA kv=8) expert
d_ff=8192 vocab=202048. FSDP + fused FL strategy (400B params)."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=("C", "C", "C", "A"),
    chunk=8192,
    n_experts=128,
    moe_every=2,              # MoE interleaved with dense layers (Maverick)
    top_k=1,
    ffn_act="swiglu",
    rope_theta=500000.0,
    fl_strategy="fused",
    fsdp=True,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
))

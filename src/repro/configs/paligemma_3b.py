"""PaliGemma-3B [arXiv:2407.07726] — VLM: SigLIP vision encoder (STUB —
input_specs provides 256 precomputed patch embeddings) + Gemma decoder
backbone. 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216."""
from repro.configs.base import ModelConfig, register_arch

CONFIG = register_arch(ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    block_pattern=("A",),
    vis_tokens=256,
    ffn_act="geglu",
    emb_scale=True,
    fl_strategy="two_phase",
    citation="arXiv:2407.07726",
))

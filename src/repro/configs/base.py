"""Config dataclasses + registry for architectures, shapes, meshes, FL.

Every assigned architecture registers a ``ModelConfig`` via
``register_arch``; ``get_arch(name)`` returns it and
``reduced(cfg)`` derives the CPU smoke-test variant (2 layers,
d_model<=512, <=4 experts) from the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Optional, Tuple

# Block-type codes used in ``block_pattern`` (cycled over layers):
#   "A"  global (full) attention
#   "L"  local / sliding-window attention
#   "C"  chunked attention (llama4-style iRoPE chunks)
#   "R"  RG-LRU recurrent block (recurrentgemma)
#   "W"  RWKV6 time-mix block
ATTN_BLOCKS = ("A", "L", "C")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    block_pattern: Tuple[str, ...] = ("A",)
    window: int = 4096              # sliding window for "L" blocks
    chunk: int = 8192               # chunk size for "C" blocks
    attn_softcap: float = 0.0       # gemma2-style soft capping (0 = off)
    logit_softcap: float = 0.0
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0              # 0 -> dense FFN
    moe_every: int = 1              # MoE on layers with i % moe_every == moe_every-1
    top_k: int = 1
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # ffn activation: "swiglu" | "geglu" | "gelu"
    ffn_act: str = "swiglu"
    # enc-dec (whisper)
    enc_layers: int = 0             # 0 -> decoder-only
    enc_frames: int = 1500          # stub audio frontend output length
    # vlm
    vis_tokens: int = 0             # >0 -> prefix of stub patch embeddings
    # recurrent (rglru / rwkv)
    rg_lru_dim: int = 0             # 0 -> d_model
    conv1d_width: int = 4
    # embeddings
    tie_embeddings: bool = True
    emb_scale: bool = False         # gemma-style sqrt(d_model) scaling
    norm_eps: float = 1e-6
    # distribution
    fl_strategy: str = "two_phase"  # "two_phase" | "fused"
    fsdp: bool = False              # shard params over data axis too
    remat: bool = True
    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_types(self) -> Tuple[str, ...]:
        """Per-layer block type, cycling ``block_pattern``."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def subquadratic(self) -> bool:
        """True if no layer needs an unbounded full-attention KV cache,
        or the arch is explicitly long-context capable (see DESIGN.md)."""
        types = set(self.layer_types())
        if types <= {"R", "W", "L", "C"}:
            return True
        # gemma2 / llama4: alternating local(+chunked)/global — decode is
        # O(n) per token; we allow long_500k (global layers keep a sharded
        # full cache). See DESIGN.md §4.1.
        return "L" in types or "C" in types

    def param_count(self) -> int:
        """Analytic parameter count (embedding + per-layer + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        qkv = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
        attn = qkv + self.n_heads * hd * d
        if self.ffn_act in ("swiglu", "geglu"):
            ffn_dense = 3 * d * f
        else:
            ffn_dense = 2 * d * f
        total = v * d  # embeddings
        if not self.tie_embeddings:
            total += v * d
        for li, t in enumerate(self.layer_types()):
            total += 2 * d  # norms
            if t in ATTN_BLOCKS:
                total += attn
            elif t == "R":
                rd = self.rg_lru_dim or d
                total += 2 * d * rd + rd * d + 3 * rd  # linear in/out + gates
            elif t == "W":
                total += 4 * d * d + 2 * d  # r,k,v,o + decay params (approx)
            if self.is_moe_layer(li):
                total += self.n_experts * ffn_dense + d * self.n_experts
            else:
                total += ffn_dense
        total += self.enc_layers * (attn + ffn_dense + 4 * d)
        if self.is_encdec:
            total += self.num_layers * attn  # cross-attention
        return total

    def is_moe_layer(self, layer_idx: int) -> bool:
        return (self.n_experts > 0 and self.layer_types()[layer_idx] != "W"
                and layer_idx % self.moe_every == self.moe_every - 1)

    @property
    def n_moe_layers(self) -> int:
        return sum(self.is_moe_layer(i) for i in range(self.num_layers))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        per_exp = (3 if self.ffn_act in ("swiglu", "geglu") else 2) * d * f
        inactive = (self.n_experts - self.top_k) * per_exp * self.n_moe_layers
        return self.param_count() - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class FLConfig:
    """Cost-TrustFL hyper-parameters (paper §IV / §V-A)."""
    n_clouds: int = 3
    clients_per_cloud: int = 30
    clients_per_round: int = 30          # m in Eq. 10
    malicious_frac: float = 0.3
    attack: str = "none"                 # any repro.core.attacks.UPDATE_ATTACKS
    attack_scale: float = 10.0           # sign_flip/scaling/ipm/collusion knob
    gaussian_sigma: float = 1.0
    attack_z: float = 1.0                # ALIE mean − z·std evasion margin
    local_epochs: int = 5
    local_batch: int = 32
    lr: float = 0.01
    server_lr: float = 1.0
    rounds: int = 200
    ema_gamma: float = 0.9               # Eq. 9
    cost_lambda: float = 0.3             # λ in Eq. 4
    c_intra: float = 0.01                # $/GB intra-cloud
    c_cross: float = 0.09                # $/GB cross-cloud egress (AWS)
    ref_samples: int = 100
    dirichlet_alpha: float = 0.5
    aggregator: str = "cost_trustfl"     # or fedavg|krum|trimmed_mean|median|fltrust
    sketch_dim: int = 128                # fused-strategy lm-head grad sketch
    # gradient compression (repro.compress)
    compressor: str = "none"             # none|topk|qsgd
    compress_ratio: float = 0.1          # top-k kept fraction
    qsgd_levels: int = 15                # QSGD states = 2*levels+1 (5 bits)
    link_policy: str = "cross_only"      # none|cross_only|intra_only|all
    # Eq. 7 contribution score: "scalar" = paper's norm-damped cosine,
    # "multi" = scalar gated by the adaptive multi-feature trust vector
    # (repro.core.features; OptiGradTrust/FLARE-style)
    trust_features: str = "scalar"


_ARCHES: Dict[str, ModelConfig] = {}


def register_arch(cfg: ModelConfig) -> ModelConfig:
    _ARCHES[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ModelConfig:
    if name not in _ARCHES:
        # import side-effect registration
        from repro.configs import ALL_ARCH_MODULES  # noqa: F401
    if name not in _ARCHES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCHES)}")
    return _ARCHES[name]


def list_arches() -> Tuple[str, ...]:
    from repro.configs import ALL_ARCH_MODULES  # noqa: F401
    return tuple(sorted(_ARCHES))


def reduced(cfg: ModelConfig, *, d_model: int = 256, layers: int = 2) -> ModelConfig:
    """Smoke-test variant of the same family: 2 layers, d_model<=512,
    <=4 experts, small vocab/window — runs one step on CPU."""
    n_heads = max(2, min(4, cfg.n_heads))
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    # keep the pattern's first `layers` entries so every block type in the
    # family is exercised when layers >= len(pattern)
    pat = cfg.layer_types()[: max(layers, 1)]
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=d_model // n_heads,
        d_ff=d_model * 3,
        vocab_size=512,
        block_pattern=tuple(pat),
        window=64,
        chunk=64,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        enc_layers=2 if cfg.enc_layers else 0,
        enc_frames=16 if cfg.enc_layers else 1500,
        vis_tokens=8 if cfg.vis_tokens else 0,
        rg_lru_dim=d_model if cfg.rg_lru_dim else 0,
        rope_theta=10000.0,
        fsdp=False,
        remat=False,
    )

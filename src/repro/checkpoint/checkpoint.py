"""Flat-npz checkpointing with JSON metadata (step, config, reputation
state). Pytrees are flattened with '/'-joined key paths; restore rebuilds
into a provided template tree (shape/dtype validated)."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree: Any, *, step: int = 0,
                    metadata: Optional[Dict[str, Any]] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    meta = {"step": step, "n_arrays": len(flat)}
    meta.update(metadata or {})
    with open(os.path.join(path, "metadata.json"), "w") as f:
        json.dump(meta, f, indent=2)


def restore_checkpoint(path: str, template: Any
                       ) -> Tuple[Any, Dict[str, Any]]:
    with np.load(os.path.join(path, "arrays.npz")) as npz:
        flat = {k: npz[k] for k in npz.files}
    with open(os.path.join(path, "metadata.json")) as f:
        meta = json.load(f)

    leaves_tpl, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_k, leaf in leaves_tpl:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        if key not in flat:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)
    return tree, meta

"""The paper's four static attacks (Table I, 30% malicious, α=0.5) as
registered scenarios. ``sign_flip`` pins ``attack_scale=1.0`` — the
paper's g ← −g — now that the knob is honored by the attack transform;
``scaling`` keeps the model-replacement ×10."""
from __future__ import annotations

from repro.scenarios.base import Scenario, register_scenario

LABEL_FLIP = register_scenario(Scenario(
    name="label_flip", level="static",
    description="30% of clients train on randomly permuted labels",
    overrides=dict(attack="label_flip", malicious_frac=0.3),
))

GAUSSIAN = register_scenario(Scenario(
    name="gaussian", level="static",
    description="malicious updates carry additive N(0, σ²) noise",
    overrides=dict(attack="gaussian", malicious_frac=0.3,
                   gaussian_sigma=1.0),
    knobs=dict(sigma=1.0),
))

SIGN_FLIP = register_scenario(Scenario(
    name="sign_flip", level="static",
    description="malicious updates negated (g ← −g)",
    overrides=dict(attack="sign_flip", malicious_frac=0.3,
                   attack_scale=1.0),
    knobs=dict(scale=1.0),
))

SCALING = register_scenario(Scenario(
    name="scaling", level="static",
    description="malicious updates amplified ×10 (model replacement)",
    overrides=dict(attack="scaling", malicious_frac=0.3,
                   attack_scale=10.0),
    knobs=dict(scale=10.0),
))

STATIC_SCENARIOS = (LABEL_FLIP, GAUSSIAN, SIGN_FLIP, SCALING)

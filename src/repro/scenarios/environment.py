"""Environment scenarios: no new update math — they stress the
*protocol* through the hook surface of ``FLServer.run_round``.

* ``dropout``      — stragglers: each selected client independently
  fails to deliver with probability ``p_drop`` (at least one always
  delivers so the round aggregates something).
* ``intermittent`` — sleeper adversaries: behave honestly for
  ``warmup`` rounds to farm EMA reputation (Eq. 9), then sign-flip.
* ``price_surge``  — dynamic egress pricing: a per-round multiplier
  schedule on ``c_cross`` rebuilds ``CostModel`` (and the Eq. 10 unit
  costs) before selection, so the cost-aware policy must track moving
  prices.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.cost import CostModel
from repro.scenarios.base import JitHooks, Scenario, register_scenario


def make_dropout_hook(p_drop: float):
    """Delivery mask: drop each selected client with prob ``p_drop``
    (deterministic in the round's ``rng``); never drop everyone."""
    def deliver(server, t, rng, sel):
        sel = np.asarray(sel, bool)
        out = sel & (rng.random(sel.shape[0]) >= p_drop)
        if not out.any() and sel.any():
            out[np.nonzero(sel)[0][0]] = True
        return out
    return deliver


def make_intermittent_hook(warmup: int):
    """Active-malice mask: all-honest before ``warmup``, the server's
    static malicious set afterwards."""
    def malicious_now(server, t):
        if t < warmup:
            return np.zeros_like(server.malicious)
        return server.malicious
    return malicious_now


def make_price_surge_hook(multipliers: Sequence[float]):
    """Round-start hook cycling a ``c_cross`` multiplier schedule."""
    mults = tuple(float(m) for m in multipliers)

    def on_round_start(server, t, rng):
        base = server.flcfg
        cm = CostModel(base.c_intra, base.c_cross * mults[t % len(mults)],
                       bytes_per_param=server.cost_model.bytes_per_param)
        server.cost_model = cm
        server.unit_costs = cm.hierarchical_unit_costs(server.topo)
    return on_round_start


DROPOUT = register_scenario(Scenario(
    name="dropout", level="environment",
    description="30% of selected clients never deliver their update",
    overrides=dict(attack="none", malicious_frac=0.0),
    knobs=dict(p_drop=0.3),
    deliver=make_dropout_hook(0.3),
    jit_hooks=JitHooks(p_drop=0.3),
))

INTERMITTENT = register_scenario(Scenario(
    name="intermittent", level="environment",
    description="honest for 3 rounds to farm reputation, then sign-flip",
    overrides=dict(attack="sign_flip", malicious_frac=0.3,
                   attack_scale=1.0),
    knobs=dict(warmup=3, scale=1.0),
    malicious_now=make_intermittent_hook(3),
    jit_hooks=JitHooks(malice_warmup=3),
))

PRICE_SURGE = register_scenario(Scenario(
    name="price_surge", level="environment",
    description="cross-cloud egress price cycles ×(1,2,4,2) per round",
    overrides=dict(attack="none", malicious_frac=0.0),
    knobs=dict(multipliers=(1.0, 2.0, 4.0, 2.0)),
    on_round_start=make_price_surge_hook((1.0, 2.0, 4.0, 2.0)),
    jit_hooks=JitHooks(price_multipliers=(1.0, 2.0, 4.0, 2.0)),
))

ENVIRONMENT_SCENARIOS = (DROPOUT, INTERMITTENT, PRICE_SURGE)

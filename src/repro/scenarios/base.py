"""Scenario engine core: a ``Scenario`` bundles FLConfig overrides, an
attack (by registry name, via the ``attack`` override) and per-round
hooks into one named, registrable unit that ``FLServer``,
``run_simulation``/``compare_methods``, the benchmarks and the test
matrix all share.

Hook surface (all optional, duck-typed against ``FLServer``):

* ``on_round_start(server, t, rng)`` — environment mutation before
  selection; e.g. dynamic egress pricing swaps ``server.cost_model`` and
  ``server.unit_costs`` so both selection (Eq. 10) and the round's $
  accounting see the new prices.
* ``deliver(server, t, rng, sel) -> sel`` — post-selection delivery
  mask; e.g. dropout/stragglers remove selected clients that never
  deliver (they neither train nor pay wire bytes).
* ``malicious_now(server, t) -> (N,) bool`` — per-round active-malice
  mask; e.g. intermittent adversaries behave honestly for a warmup
  window to farm EMA reputation (Eq. 9) before attacking.

Hooks must be deterministic given ``(server.seed, t, rng)`` — the
regression suite asserts bit-identical reruns.

**Jittable hooks** (``JitHooks``): the device-resident round engine
(``repro.federated.engine``) cannot call host hooks from inside
``lax.scan``, so scenarios that want the fast path declare their
environment as *data* instead — a dropout probability, an active-malice
warmup round, a per-round egress price multiplier schedule. A scenario
with host hooks but no ``jit_hooks`` transparently falls back to the
host round loop.

``JitHooks`` are also **shard-safe** by construction: the mesh-sharded
engine (``repro.federated.sharded``) consumes the same pure data inside
its ``shard_map``'d scan — dropout and pricing drive *replicated* (N,)
computations (identical draws on every shard), the malice warmup gates
each shard's local adversary mask. A hook design that broke this (e.g.
per-round host state) belongs in the host hooks, where the scenario
simply loses the device engines.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import TYPE_CHECKING, Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.configs.base import FLConfig

if TYPE_CHECKING:  # avoid the circular import: federated imports scenarios
    from repro.federated.server import FLServer

LEVELS = ("static", "adaptive", "environment")

RoundStartHook = Callable[["FLServer", int, np.random.Generator], None]
DeliverHook = Callable[["FLServer", int, np.random.Generator, np.ndarray],
                       np.ndarray]
MaliciousHook = Callable[["FLServer", int], np.ndarray]


@dataclass(frozen=True)
class JitHooks:
    """Environment-as-data: the pure-state equivalents of the host hooks,
    consumable from inside ``lax.scan``. Every field composes (a scenario
    may drop AND surge prices); the defaults are all no-ops.

    * ``p_drop`` — each selected client independently fails to deliver
      with this probability (at least one always delivers).
    * ``malice_warmup`` — the static malicious set is inactive for the
      first ``malice_warmup`` rounds (sleeper adversaries farming EMA).
    * ``price_multipliers`` — per-round ``c_cross`` multiplier schedule,
      cycled as ``multipliers[t % len]``; seen by Eq. 10 selection and
      the round's $ accounting alike.
    """
    p_drop: float = 0.0
    malice_warmup: int = 0
    price_multipliers: Tuple[float, ...] = (1.0,)


@dataclass(frozen=True)
class Scenario:
    """A named adversary/environment configuration.

    ``overrides`` are applied to the caller's ``FLConfig`` (attack name,
    malicious fraction, attack knobs); ``knobs`` documents the
    scenario-specific parameters baked into the hook closures (also
    rendered in the README registry table). ``jit_hooks`` is the pure
    declaration the scanned engine consumes; the host hooks remain the
    fallback for behaviors that cannot be expressed as data.
    """
    name: str
    level: str                                   # one of LEVELS
    description: str = ""
    overrides: Dict[str, Any] = field(default_factory=dict)
    knobs: Dict[str, Any] = field(default_factory=dict)
    on_round_start: Optional[RoundStartHook] = None
    deliver: Optional[DeliverHook] = None
    malicious_now: Optional[MaliciousHook] = None
    jit_hooks: Optional[JitHooks] = None

    def __post_init__(self):
        if self.level not in LEVELS:
            raise ValueError(f"level {self.level!r} not in {LEVELS}")

    @property
    def jittable(self) -> bool:
        """True when the device engine can run this scenario: either the
        pure ``jit_hooks`` declaration exists, or there is no per-round
        host behavior at all (attack-only scenarios — the update attacks
        are already jittable (N, D) transforms)."""
        if self.jit_hooks is not None:
            return True
        return (self.on_round_start is None and self.deliver is None
                and self.malicious_now is None)

    def apply(self, flcfg: FLConfig) -> FLConfig:
        """FLConfig with this scenario's overrides applied (idempotent)."""
        return replace(flcfg, **self.overrides) if self.overrides else flcfg

    # -- hook dispatch (no-ops when the hook is unset) ------------------------
    def round_start(self, server: "FLServer", t: int,
                    rng: np.random.Generator) -> None:
        if self.on_round_start is not None:
            self.on_round_start(server, t, rng)

    def delivered(self, server: "FLServer", t: int,
                  rng: np.random.Generator, sel: np.ndarray) -> np.ndarray:
        return sel if self.deliver is None else self.deliver(server, t, rng, sel)

    def active_malicious(self, server: "FLServer", t: int) -> np.ndarray:
        if self.malicious_now is None:
            return server.malicious
        return self.malicious_now(server, t)


_SCENARIOS: Dict[str, Scenario] = {}


def register_scenario(scenario: Scenario) -> Scenario:
    if scenario.name in _SCENARIOS:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    _SCENARIOS[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    if name not in _SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; known: {list_scenarios()}")
    return _SCENARIOS[name]


def list_scenarios(level: Optional[str] = None) -> Tuple[str, ...]:
    return tuple(sorted(n for n, s in _SCENARIOS.items()
                        if level is None or s.level == level))

"""Adaptive update-level adversaries (out-of-paper extensions; see
OptiGradTrust / FLARE in PAPERS.md). Each scenario only names an attack
from ``repro.core.attacks.UPDATE_ATTACKS`` — the transforms themselves
live there as jittable (N, D) functions."""
from __future__ import annotations

from repro.scenarios.base import Scenario, register_scenario

ALIE = register_scenario(Scenario(
    name="alie", level="adaptive",
    description="a-little-is-enough: hide at mean − z·std of honest rows",
    overrides=dict(attack="alie", malicious_frac=0.3, attack_z=1.0),
    knobs=dict(z=1.0),
))

IPM = register_scenario(Scenario(
    name="ipm", level="adaptive",
    description="inner-product manipulation: submit −ε·mean(honest)",
    overrides=dict(attack="ipm", malicious_frac=0.3, attack_scale=2.0),
    knobs=dict(epsilon=2.0),
))

MIN_MAX = register_scenario(Scenario(
    name="min_max", level="adaptive",
    description="largest perturbation inside the honest distance envelope",
    overrides=dict(attack="min_max", malicious_frac=0.3),
    knobs=dict(iters=20),
))

COLLUSION = register_scenario(Scenario(
    name="collusion", level="adaptive",
    description="colluders submit one agreed −mean(their updates)",
    overrides=dict(attack="collusion", malicious_frac=0.3,
                   attack_scale=1.0),
    knobs=dict(scale=1.0),
))

ADAPTIVE_SCENARIOS = (ALIE, IPM, MIN_MAX, COLLUSION)

"""Adaptive update-level adversaries (out-of-paper extensions; see
OptiGradTrust / FLARE in PAPERS.md). Each scenario only names an attack
from ``repro.core.attacks.UPDATE_ATTACKS`` — the transforms themselves
live there as jittable (N, D) functions."""
from __future__ import annotations

from repro.scenarios.base import JitHooks, Scenario, register_scenario
from repro.scenarios.environment import make_intermittent_hook

ALIE = register_scenario(Scenario(
    name="alie", level="adaptive",
    description="a-little-is-enough: hide at mean − z·std of honest rows",
    overrides=dict(attack="alie", malicious_frac=0.3, attack_z=1.0),
    knobs=dict(z=1.0),
))

# reputation-aware ALIE variants: both target the trust evaluator
# itself rather than the aggregate, stressing the multi-feature path
# (scalar Eq. 7 is norm-dominated; these hide in the norm profile).
ALIE_NORM = register_scenario(Scenario(
    name="alie_norm", level="adaptive",
    description="ALIE point rescaled to the honest median norm, so the "
                "Eq. 7 norm damp reads attackers as typical",
    overrides=dict(attack="alie_norm", malicious_frac=0.3, attack_z=1.0),
    knobs=dict(z=1.0),
))

ALIE_SLEEPER = register_scenario(Scenario(
    name="alie_sleeper", level="adaptive",
    description="honest for 2 rounds to farm reputation, then ALIE",
    overrides=dict(attack="alie", malicious_frac=0.3, attack_z=1.0),
    knobs=dict(warmup=2, z=1.0),
    malicious_now=make_intermittent_hook(2),
    jit_hooks=JitHooks(malice_warmup=2),
))

IPM = register_scenario(Scenario(
    name="ipm", level="adaptive",
    description="inner-product manipulation: submit −ε·mean(honest)",
    overrides=dict(attack="ipm", malicious_frac=0.3, attack_scale=2.0),
    knobs=dict(epsilon=2.0),
))

MIN_MAX = register_scenario(Scenario(
    name="min_max", level="adaptive",
    description="largest perturbation inside the honest distance envelope",
    overrides=dict(attack="min_max", malicious_frac=0.3),
    knobs=dict(iters=20),
))

COLLUSION = register_scenario(Scenario(
    name="collusion", level="adaptive",
    description="colluders submit one agreed −mean(their updates)",
    overrides=dict(attack="collusion", malicious_frac=0.3,
                   attack_scale=1.0),
    knobs=dict(scale=1.0),
))

ADAPTIVE_SCENARIOS = (ALIE, ALIE_NORM, ALIE_SLEEPER, IPM, MIN_MAX,
                      COLLUSION)

"""Composable adversary + environment scenarios (``repro.scenarios``).

Importing this package registers every built-in scenario; enumerate them
with ``list_scenarios()`` and plug one into ``run_simulation(...,
scenario=name_or_obj)`` / ``compare_methods(..., scenario=...)``. The
registry is what lets the regression suite and ``benchmarks/
table1_attacks.table1b_adaptive`` sweep the full scenario × method
matrix mechanically.
"""
from repro.scenarios.base import (LEVELS, JitHooks, Scenario,
                                  get_scenario, list_scenarios,
                                  register_scenario)
from repro.scenarios.static import STATIC_SCENARIOS
from repro.scenarios.adaptive import ADAPTIVE_SCENARIOS
from repro.scenarios.environment import (ENVIRONMENT_SCENARIOS,
                                         make_dropout_hook,
                                         make_intermittent_hook,
                                         make_price_surge_hook)

__all__ = [
    "LEVELS", "JitHooks", "Scenario", "get_scenario", "list_scenarios",
    "register_scenario", "STATIC_SCENARIOS", "ADAPTIVE_SCENARIOS",
    "ENVIRONMENT_SCENARIOS", "make_dropout_hook", "make_intermittent_hook",
    "make_price_surge_hook",
]

"""Cost-aware gradient compression for the multi-cloud hierarchy.

Three codecs — ``topk`` (error-feedback sparsification), ``qsgd``
(unbiased stochastic quantization), ``none`` (fp32 passthrough) — plus a
per-link policy layer that assigns a codec to each edge of the
client → edge → global upload path, so cheap intra-cloud links can stay
uncompressed while expensive cross-cloud egress compresses aggressively.

Hot paths are fused Pallas kernels (repro.kernels.topk_mask / quantize,
interpret=True on CPU); exact wire bytes feed repro.core.cost.CostModel.
"""
from repro.compress.base import (Codec, CompressedUpdate, ef_step,
                                 ef_step_masked, make_codec)
from repro.compress.policy import (POLICIES, LinkPolicy, build_link_policy,
                                   policy_from_flcfg)
from repro.compress.qsgd import QSGDCodec
from repro.compress.topk import TopKCodec

__all__ = ["Codec", "CompressedUpdate", "ef_step", "ef_step_masked",
           "make_codec",
           "POLICIES", "LinkPolicy", "build_link_policy",
           "policy_from_flcfg", "QSGDCodec", "TopKCodec"]

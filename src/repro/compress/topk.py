"""Top-k sparsification codec (error feedback applied by the caller).

Wire format per update: a 4-byte length header, then k (value, index)
pairs — fp16 value + int32 index — so the exact payload is
``4 + 6k`` bytes against ``4D`` uncompressed. At ratio 0.1 that is a
6.6x reduction on the wire.

The hot path (``roundtrip``) uses the fused Pallas threshold+mask kernel
and returns the dense decompressed form directly; values pass through
fp16 so the round-trip distortion matches the wire format exactly (the
error-feedback residual absorbs it).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.compress.base import Codec, CompressedUpdate, register_codec
from repro.kernels import ops

Array = jax.Array

_HEADER_BYTES = 4      # entry count
_VALUE_BYTES = 2       # fp16 value
_INDEX_BYTES = 4       # int32 position


@register_codec("topk")
@dataclass(frozen=True)
class TopKCodec(Codec):
    """Keep the ``ratio`` fraction of largest-magnitude entries per row."""
    ratio: float = 0.1
    name = "topk"

    @property
    def is_identity(self) -> bool:
        return self.ratio >= 1.0

    def k_for(self, d: int) -> int:
        return max(1, min(d, int(round(self.ratio * d))))

    def payload_bytes(self, d: int) -> int:
        if self.is_identity:
            return super().payload_bytes(d)
        return _HEADER_BYTES + self.k_for(d) * (_VALUE_BYTES + _INDEX_BYTES)

    def encode(self, x: Array, key: Array) -> CompressedUpdate:
        k = self.k_for(x.shape[1])
        _, idx = jax.lax.top_k(jnp.abs(x), k)                  # (N, k)
        vals = jnp.take_along_axis(x, idx, axis=1).astype(jnp.float16)
        return CompressedUpdate("topk", {"values": vals, "indices": idx},
                                tuple(x.shape),
                                self.payload_bytes(x.shape[1]))

    def decode(self, c: CompressedUpdate) -> Array:
        n, d = c.shape
        out = jnp.zeros((n, d), jnp.float32)
        rows = jnp.arange(n)[:, None]
        return out.at[rows, c.data["indices"]].set(
            c.data["values"].astype(jnp.float32))

    def roundtrip(self, x: Array, key: Array, row_ids=None) -> Array:
        if self.is_identity:
            return x
        masked = ops.topk_mask(x, k=self.k_for(x.shape[1]))
        # match the fp16 wire precision of the values
        return masked.astype(jnp.float16).astype(x.dtype)

"""Codec protocol + shared machinery for gradient compression.

A *codec* maps a batch of flat client updates (N, D) to a wire
representation and back. The simulation only ever needs the round-trip
(what the receiver decodes) plus the exact wire size, so the hot path is
``roundtrip`` — a fused Pallas-kernel pass that never materializes the
packed payload — while ``encode``/``decode`` expose the structured wire
form for inspection and tests.

Error feedback (``ef_step``) keeps a per-sender residual r_t:

    y_t = x_t + r_{t-1};   x̂_t = roundtrip(y_t);   r_t = y_t - x̂_t

which telescopes to Σ x̂_t = Σ x_t + r_0 - r_T — no signal is ever lost,
only delayed, which is what keeps trust/Shapley statistics (computed on
the decompressed x̂) honest under aggressive compression.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

FP32_BYTES = 4


@dataclass(frozen=True)
class CompressedUpdate:
    """Structured wire form of one batch of updates."""
    kind: str                       # codec name
    data: Dict[str, Any]            # codec-specific arrays
    shape: Tuple[int, int]          # uncompressed (N, D)
    nbytes_per_row: int             # exact wire bytes for ONE update


class Codec:
    """Base codec: fp32 passthrough (the ``none`` codec)."""
    name = "none"

    @property
    def is_identity(self) -> bool:
        return True

    def payload_bytes(self, d: int) -> int:
        """Exact wire bytes for one D-dim update."""
        return FP32_BYTES * d

    def encode(self, x: Array, key: Array) -> CompressedUpdate:
        return CompressedUpdate(self.name, {"values": x}, tuple(x.shape),
                                self.payload_bytes(x.shape[1]))

    def decode(self, c: CompressedUpdate) -> Array:
        return c.data["values"]

    def roundtrip(self, x: Array, key: Array,
                  row_ids: Optional[Array] = None) -> Array:
        """decode(encode(x)) without materializing the wire form.

        ``row_ids`` (optional (N,) int) are the SENDER identities of the
        rows — stochastic codecs fold them into their noise stream so a
        client's randomness depends on who sent the row, never on where
        the row happens to sit in the batch (the property that makes
        QSGD shard-decomposable). Defaults to ``arange(N)``, which is
        already the sender id for full-population batches such as the
        (K,) edge uplinks."""
        return x


def ef_step(codec: Codec, x: Array, residual: Array, key: Array,
            row_ids: Optional[Array] = None) -> Tuple[Array, Array]:
    """One error-feedback round: returns (x̂ transmitted, new residual)."""
    if codec.is_identity:
        return x, residual
    y = x + residual
    x_hat = codec.roundtrip(y, key, row_ids)
    return x_hat, y - x_hat


def ef_step_masked(codec: Codec, x: Array, residual: Array, row_mask: Array,
                   key: Array, row_ids: Optional[Array] = None
                   ) -> Tuple[Array, Array]:
    """Pure, fixed-shape EF round for the scanned engine: rows where
    ``row_mask`` is False pass through untouched and KEEP their residual
    (nothing crossed the wire for them). No mutable buffers — the caller
    gathers/scatters the per-sender residual rows explicitly, so the
    whole step is a jittable function of (x, residual)."""
    if codec.is_identity:
        return x, residual
    y = x + residual
    x_hat = codec.roundtrip(y, key, row_ids)
    keep = row_mask[:, None]
    return (jnp.where(keep, x_hat, x),
            jnp.where(keep, y - x_hat, residual))


_REGISTRY: Dict[str, Any] = {}


def register_codec(name: str):
    def deco(cls):
        _REGISTRY[name] = cls
        return cls
    return deco


def make_codec(name: str, *, ratio: float = 0.1, levels: int = 15) -> Codec:
    """Codec factory: ``none`` | ``topk`` | ``qsgd``."""
    if name in ("none", None, ""):
        return Codec()
    if name not in _REGISTRY:
        known = ["none"] + sorted(_REGISTRY)
        raise ValueError(f"unknown compressor {name!r}; known: {known}")
    if name == "topk":
        return _REGISTRY[name](ratio=ratio)
    return _REGISTRY[name](levels=levels)

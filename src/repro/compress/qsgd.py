"""QSGD-style stochastic quantization codec (linf-scaled, unbiased).

Per update: levels q ∈ [-L, L] with q = sign(x)·floor(|x|/s·L + u),
s = max|x|, u ~ U[0,1) — so E[decode(encode(x))] = x exactly
(stochastic rounding is unbiased coordinate-wise). Wire format: a
4-byte fp32 scale plus D entries packed at ceil(log2(2L+1)) bits each.
L = 15 → 5 bits/coordinate → 6.4x below fp32.

The server wraps every non-identity codec — this one included — in
error feedback (``ef_step``); for an unbiased codec the residual is
zero-mean rounding noise, so EF only tightens the variance while the
expectation guarantee above does the heavy lifting.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.compress.base import (Codec, CompressedUpdate, FP32_BYTES,
                                 register_codec)
from repro.kernels import ops, ref

Array = jax.Array


@register_codec("qsgd")
@dataclass(frozen=True)
class QSGDCodec(Codec):
    """Stochastic quantization to 2·levels+1 states per coordinate."""
    levels: int = 15
    name = "qsgd"

    @property
    def is_identity(self) -> bool:
        return False

    @property
    def bits_per_coord(self) -> int:
        return max(1, math.ceil(math.log2(2 * self.levels + 1)))

    def payload_bytes(self, d: int) -> int:
        return FP32_BYTES + math.ceil(d * self.bits_per_coord / 8)

    def encode(self, x: Array, key: Array,
               row_ids: Optional[Array] = None) -> CompressedUpdate:
        scale = jnp.max(jnp.abs(x), axis=1)                    # (N,)
        # rounding noise is keyed PER SENDER (fold_in the row's client
        # id), never per matrix layout: a client's noise stream is the
        # same whether its row sits in a compact selected matrix, a
        # shard-local block, or the host loop's delivered subset — the
        # property the sharded engine's parity contract relies on.
        if row_ids is None:
            row_ids = jnp.arange(x.shape[0])
        noise = jax.vmap(
            lambda r: jax.random.uniform(jax.random.fold_in(key, r),
                                         (x.shape[1],)))(
            jnp.asarray(row_ids))
        q = ops.stochastic_quantize(x, scale, noise, levels=self.levels)
        return CompressedUpdate("qsgd", {"q": q, "scale": scale},
                                tuple(x.shape),
                                self.payload_bytes(x.shape[1]))

    def decode(self, c: CompressedUpdate) -> Array:
        return ref.dequantize_ref(c.data["q"], c.data["scale"], self.levels)

    def roundtrip(self, x: Array, key: Array,
                  row_ids: Optional[Array] = None) -> Array:
        c = self.encode(x, key, row_ids)
        return self.decode(c).astype(x.dtype)

"""QSGD-style stochastic quantization codec (linf-scaled, unbiased).

Per update: levels q ∈ [-L, L] with q = sign(x)·floor(|x|/s·L + u),
s = max|x|, u ~ U[0,1) — so E[decode(encode(x))] = x exactly
(stochastic rounding is unbiased coordinate-wise). Wire format: a
4-byte fp32 scale plus D entries packed at ceil(log2(2L+1)) bits each.
L = 15 → 5 bits/coordinate → 6.4x below fp32.

The server wraps every non-identity codec — this one included — in
error feedback (``ef_step``); for an unbiased codec the residual is
zero-mean rounding noise, so EF only tightens the variance while the
expectation guarantee above does the heavy lifting.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.compress.base import (Codec, CompressedUpdate, FP32_BYTES,
                                 register_codec)
from repro.kernels import ops, ref

Array = jax.Array


@register_codec("qsgd")
@dataclass(frozen=True)
class QSGDCodec(Codec):
    """Stochastic quantization to 2·levels+1 states per coordinate."""
    levels: int = 15
    name = "qsgd"

    @property
    def is_identity(self) -> bool:
        return False

    @property
    def bits_per_coord(self) -> int:
        return max(1, math.ceil(math.log2(2 * self.levels + 1)))

    def payload_bytes(self, d: int) -> int:
        return FP32_BYTES + math.ceil(d * self.bits_per_coord / 8)

    def encode(self, x: Array, key: Array) -> CompressedUpdate:
        scale = jnp.max(jnp.abs(x), axis=1)                    # (N,)
        noise = jax.random.uniform(key, x.shape)
        q = ops.stochastic_quantize(x, scale, noise, levels=self.levels)
        return CompressedUpdate("qsgd", {"q": q, "scale": scale},
                                tuple(x.shape),
                                self.payload_bytes(x.shape[1]))

    def decode(self, c: CompressedUpdate) -> Array:
        return ref.dequantize_ref(c.data["q"], c.data["scale"], self.levels)

    def roundtrip(self, x: Array, key: Array) -> Array:
        c = self.encode(x, key)
        return self.decode(c).astype(x.dtype)

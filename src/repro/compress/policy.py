"""Per-link compression policy (the paper's hierarchy-first cost logic).

Two link classes exist in the topology:

* **intra** — client → edge-aggregator uplinks (always within a cloud)
  and the edge → global uplink of the cloud co-located with the global
  aggregator; priced at ``c_intra``.
* **cross** — edge → global uplinks of every other cloud (and, on the
  flat baseline path, the direct uplink of any client outside the
  aggregator cloud); priced at ``c_cross``.

A ``LinkPolicy`` assigns one codec per class. The default,
``cross_only``, keeps cheap intra-cloud traffic at full fidelity and
compresses only the expensive egress links — mirroring how the paper's
hierarchy concentrates savings where the $/GB is 9x higher.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

from repro.compress.base import Codec, make_codec

POLICIES = ("none", "cross_only", "intra_only", "all")


@dataclass(frozen=True)
class LinkPolicy:
    """Resolved codec per link class."""
    intra: Codec
    cross: Codec

    @property
    def any_active(self) -> bool:
        return not (self.intra.is_identity and self.cross.is_identity)

    def payload_vectors(self, topo, d_params: int, *,
                        hierarchical: bool = True
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact wire bytes per client uplink (N,) and per edge→global
        uplink (K,) under this policy — the single source of the
        link-class → payload mapping used by both the server's billing
        and reporting tools. Hierarchical: every client hop is intra;
        flat: a client's one hop is intra iff co-located with the
        aggregator. The aggregator cloud's edge uplink is intra-class."""
        intra_b = self.intra.payload_bytes(d_params)
        cross_b = self.cross.payload_bytes(d_params)
        if hierarchical:
            client = np.full(topo.n_clients, intra_b, np.float64)
        else:
            same = topo.cloud_of == topo.aggregator_cloud
            client = np.where(same, intra_b, cross_b).astype(np.float64)
        edge = np.full(topo.n_clouds, cross_b, np.float64)
        edge[topo.aggregator_cloud] = intra_b
        return client, edge


def build_link_policy(compressor: str = "none", *, ratio: float = 0.1,
                      levels: int = 15, link_policy: str = "cross_only"
                      ) -> LinkPolicy:
    """Resolve (compressor, link_policy) config knobs into per-link codecs."""
    if link_policy not in POLICIES:
        raise ValueError(f"unknown link_policy {link_policy!r}; "
                         f"known: {POLICIES}")
    codec = make_codec(compressor, ratio=ratio, levels=levels)
    identity = Codec()
    if codec.is_identity or link_policy == "none":
        return LinkPolicy(intra=identity, cross=identity)
    if link_policy == "cross_only":
        return LinkPolicy(intra=identity, cross=codec)
    if link_policy == "intra_only":
        return LinkPolicy(intra=codec, cross=identity)
    return LinkPolicy(intra=codec, cross=codec)


def policy_from_flcfg(flcfg) -> LinkPolicy:
    """Build the LinkPolicy an ``FLConfig`` describes."""
    return build_link_policy(flcfg.compressor, ratio=flcfg.compress_ratio,
                             levels=flcfg.qsgd_levels,
                             link_policy=flcfg.link_policy)

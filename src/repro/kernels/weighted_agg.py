"""Pallas TPU kernel: fused trust-weighted aggregation (Eq. 12 + Eq. 13).

out[d] = Σ_i TS_i · (‖g_ref‖ / ‖g_i‖) · G[i, d]  /  Σ_i TS_i

Grid tiles the D axis; each step loads an (N, BD) VMEM tile of G plus the
(N,) weight vector (computed once on host-of-grid from TS/norms — cheap),
and emits the (BD,) weighted column sum as a single (1, N) x (N, BD)
MXU matmul. N (clients) is small (<=256), so a full N-column strip fits
VMEM at BD=512: 256 x 512 x 4B = 512 KiB."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(g_blk, w_blk, out_blk):
    g = g_blk[...].astype(jnp.float32)          # (N, BD)
    w = w_blk[...].astype(jnp.float32)          # (1, N)
    out_blk[...] = (w @ g)                      # (1, BD)


def weighted_agg(grads: Array, ts: Array, norms: Array, ref_norm: Array,
                 *, block_d: int = 512, eps: float = 1e-12,
                 interpret: bool = True) -> Array:
    """(N, D) x weights -> (D,) aggregate. See ref.weighted_agg_ref."""
    n, d = grads.shape
    bd = min(block_d, d)
    pd = (-d) % bd
    g = jnp.pad(grads, ((0, 0), (0, pd)))
    w = (ts.astype(jnp.float32)
         * (ref_norm / jnp.maximum(norms.astype(jnp.float32), eps))
         / jnp.maximum(jnp.sum(ts.astype(jnp.float32)), eps))[None, :]
    dd = g.shape[1]

    out = pl.pallas_call(
        _kernel,
        grid=(dd // bd,),
        in_specs=[
            pl.BlockSpec((n, bd), lambda j: (0, j)),
            pl.BlockSpec((1, n), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bd), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, dd), jnp.float32),
        interpret=interpret,
    )(g, w)
    return out[0, :d]

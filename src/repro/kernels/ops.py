"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only; TPU is the
compilation TARGET). On real TPU hardware pass interpret=False.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.linear_scan import linear_scan as _linear_scan
from repro.kernels.quantize import stochastic_quantize as _stochastic_quantize
from repro.kernels.topk_mask import topk_mask as _topk_mask
from repro.kernels.trust_features import trust_features as _trust_features
from repro.kernels.trust_score import trust_score as _trust_score
from repro.kernels.weighted_agg import weighted_agg as _weighted_agg

Array = jax.Array


@partial(jax.jit, static_argnames=("block_n", "block_d", "interpret"))
def trust_score(grads: Array, ref: Array, reputation: Array, *,
                block_n: int = 8, block_d: int = 512,
                interpret: bool = True) -> Tuple[Array, Array, Array]:
    """Fused Eq. 7 + Eq. 11 statistics: (phi, ts, norms) over (N, D)."""
    return _trust_score(grads, ref, reputation, block_n=block_n,
                        block_d=block_d, interpret=interpret)


@partial(jax.jit, static_argnames=("block_n", "block_d", "interpret"))
def trust_features(grads: Array, refs: Array, gbar: Array, med: Array,
                   w: Array, *, block_n: int = 8, block_d: int = 512,
                   interpret: bool = True) -> Array:
    """Fused multi-feature trust pass: (M, D) -> (M, N_FEATURES)."""
    return _trust_features(grads, refs, gbar, med, w, block_n=block_n,
                           block_d=block_d, interpret=interpret)


@partial(jax.jit, static_argnames=("block_d", "interpret"))
def weighted_agg(grads: Array, ts: Array, norms: Array, ref_norm: Array, *,
                 block_d: int = 512, interpret: bool = True) -> Array:
    """Fused Eq. 12 + Eq. 13 aggregation: (N, D) -> (D,)."""
    return _weighted_agg(grads, ts, norms, ref_norm, block_d=block_d,
                         interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "block_b", "interpret"))
def linear_scan(a: Array, b: Array, *, chunk: int = 32, block_b: int = 8,
                interpret: bool = True) -> Array:
    """Diagonal linear recurrence h_t = a_t*h_{t-1} + b_t over axis 1."""
    return _linear_scan(a, b, chunk=chunk, block_b=block_b,
                        interpret=interpret)


@partial(jax.jit, static_argnames=("k", "block_n", "block_d", "interpret"))
def topk_mask(grads: Array, *, k: int, block_n: int = 8, block_d: int = 512,
              interpret: bool = True) -> Array:
    """Keep the k largest-|.| entries per row of (N, D), zero the rest
    (dense decompressed form; ties at the threshold are kept)."""
    thr = jax.lax.top_k(jnp.abs(grads), k)[0][:, -1]
    return _topk_mask(grads, thr, block_n=block_n, block_d=block_d,
                      interpret=interpret)


@partial(jax.jit, static_argnames=("levels", "block_n", "block_d",
                                   "interpret"))
def stochastic_quantize(x: Array, scale: Array, noise: Array, *, levels: int,
                        block_n: int = 8, block_d: int = 512,
                        interpret: bool = True) -> Array:
    """QSGD stochastic-rounding quantize to int32 levels in [-L, L]."""
    return _stochastic_quantize(x, scale, noise, levels=levels,
                                block_n=block_n, block_d=block_d,
                                interpret=interpret)

"""Pallas TPU kernel: QSGD-style stochastic-rounding quantization.

Input: X (N, D) per-client updates, scale (N, 1) per-row max-|x| scales
and U (N, D) uniform [0, 1) noise; static ``levels`` L. Output int32
levels q in [-L, L] with

    q[i, d] = sign(x) * floor(|x| / scale_i * L + u)

so that E_u[q * scale / L] = x — the unbiasedness the trust statistics
rely on (they are computed on dequantized updates downstream).

The randomness is an explicit input rather than ``pltpu.prng_random_bits``
so the kernel is bit-reproducible under ``interpret=True`` on CPU (this
container) and trivially checkable against ``ref.stochastic_quantize_ref``;
on real TPU hardware the noise tile streams from HBM alongside X.

TPU mapping: grid over N-blocks x D-blocks, all element-wise VPU work on
(BN, BD) VMEM tiles; the (BN, 1) scale column rides along each row block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(x_blk, s_blk, u_blk, q_blk, *, levels: int, eps: float):
    x = x_blk[...].astype(jnp.float32)              # (BN, BD)
    s = jnp.maximum(s_blk[...].astype(jnp.float32), eps)   # (BN, 1)
    v = x / s * levels                              # |v| <= L by construction
    xi = jnp.floor(jnp.abs(v) + u_blk[...].astype(jnp.float32))
    xi = jnp.minimum(xi, float(levels))
    q_blk[...] = (jnp.sign(v) * xi).astype(jnp.int32)


def stochastic_quantize(x: Array, scale: Array, noise: Array, *,
                        levels: int, block_n: int = 8, block_d: int = 512,
                        eps: float = 1e-12, interpret: bool = True) -> Array:
    """Quantize (N, D) to int32 levels in [-levels, levels].

    ``scale``: (N,) per-row scales (max |x| for the QSGD linf variant).
    ``noise``: (N, D) uniform [0, 1) — supplies the stochastic rounding.
    """
    n, d = x.shape
    bn = min(block_n, n)
    bd = min(block_d, d)
    pn = (-n) % bn
    pd = (-d) % bd
    xp = jnp.pad(x, ((0, pn), (0, pd)))
    up = jnp.pad(noise, ((0, pn), (0, pd)))
    sp = jnp.pad(scale.reshape(-1, 1), ((0, pn), (0, 0)))
    nn, dd = xp.shape

    q = pl.pallas_call(
        functools.partial(_kernel, levels=levels, eps=eps),
        grid=(nn // bn, dd // bd),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nn, dd), jnp.int32),
        interpret=interpret,
    )(xp, sp, up)
    return q[:n, :d]

"""Pallas TPU kernels for the perf-critical hot spots (DESIGN.md §6):
trust_score (Eq. 7+11), weighted_agg (Eq. 12+13), linear_scan (RG-LRU),
topk_mask + stochastic_quantize (repro.compress gradient codecs).
Each has a pure-jnp oracle in ref.py; ops.py holds the jit'd wrappers."""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]

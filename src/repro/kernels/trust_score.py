"""Pallas TPU kernel: fused per-client trust scoring (Eq. 7 + Eq. 11).

Input: G (N, D) per-client last-layer gradients, ref (D,) reference
gradient, rep (N,) reputations. One pass over G computes, per client,
<g_i, ḡ>, <g_i, ref>, ||g_i||² — then φ and TS on the host of the grid.

TPU mapping: grid over D-blocks (reduction dim) x N-blocks; each step
loads a (BN, BD) VMEM tile of G and the matching (BD,) slices of ref and
the precomputed column-mean ḡ, accumulating the three dot products in a
(BN, 3) VMEM scratch. The final D-block writes the scores. MXU-friendly:
BD is a multiple of 128 and the inner ops are row reductions.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel(g_ref_blk, gbar_blk, ref_blk, rep_blk, phi_out, ts_out,
            norm_out, acc, *, n_dblocks: int, eps: float):
    d_idx = pl.program_id(1)

    @pl.when(d_idx == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    g = g_ref_blk[...].astype(jnp.float32)          # (BN, BD)
    gbar = gbar_blk[...].astype(jnp.float32)        # (1, BD)
    ref = ref_blk[...].astype(jnp.float32)          # (1, BD)

    acc[:, 0] += jnp.sum(g * gbar, axis=1)          # <g_i, ḡ>
    acc[:, 1] += jnp.sum(g * ref, axis=1)           # <g_i, ref>
    acc[:, 2] += jnp.sum(g * g, axis=1)             # ||g_i||²
    acc[:, 3] += jnp.sum(gbar * gbar, axis=1)       # ||ḡ||² (bcast rows)
    acc[:, 4] += jnp.sum(ref * ref, axis=1)         # ||ref||²

    @pl.when(d_idx == n_dblocks - 1)
    def _finalize():
        dot_bar = acc[:, 0]
        dot_ref = acc[:, 1]
        norms = jnp.sqrt(jnp.maximum(acc[:, 2], 0.0))
        nbar = jnp.sqrt(jnp.maximum(acc[:, 3], 0.0))
        nref = jnp.sqrt(jnp.maximum(acc[:, 4], 0.0))
        cos_bar = dot_bar / jnp.maximum(norms * nbar, eps)
        cos_ref = dot_ref / jnp.maximum(norms * nref, eps)
        phi_out[...] = jnp.maximum(cos_bar, 0.0) * norms
        ts_out[...] = jnp.maximum(cos_ref, 0.0) * rep_blk[...]
        norm_out[...] = norms


def trust_score(grads: Array, ref: Array, reputation: Array, *,
                block_n: int = 8, block_d: int = 512,
                eps: float = 1e-12, interpret: bool = True
                ) -> Tuple[Array, Array, Array]:
    """Fused (φ, TS, ‖g‖) over (N, D). Pads N and D to block multiples."""
    n, d = grads.shape
    bn = min(block_n, n)
    bd = min(block_d, d)
    pn = (-n) % bn
    pd = (-d) % bd
    g = jnp.pad(grads, ((0, pn), (0, pd)))
    r = jnp.pad(ref, (0, pd))[None, :]
    rep = jnp.pad(reputation, (0, pn))
    gbar = jnp.mean(g[:n].astype(jnp.float32), axis=0,
                    keepdims=True).astype(g.dtype)     # (1, D̃)
    nn, dd = g.shape
    n_dblocks = dd // bd

    phi, ts, norms = pl.pallas_call(
        functools.partial(_kernel, n_dblocks=n_dblocks, eps=eps),
        grid=(nn // bn, n_dblocks),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((1, bd), lambda i, j: (0, j)),
            pl.BlockSpec((1, bd), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((nn,), jnp.float32)] * 3,
        scratch_shapes=[pltpu.VMEM((bn, 8), jnp.float32)],
        interpret=interpret,
    )(g, gbar, r, rep)
    return phi[:n], ts[:n], norms[:n]

"""Pallas TPU kernel: chunked diagonal linear recurrence
h_t = a_t ⊙ h_{t-1} + b_t (the RG-LRU state update, DESIGN.md §6).

TPU mapping: grid = (B-blocks, T-chunks) with the time axis iterated
sequentially (TPU grids execute in order, last axis fastest), carrying the
(BB, D) running state in a VMEM scratch across chunk steps. Within a
chunk the recurrence runs as an unrolled loop over the chunk's rows —
each row is a (BB, D) VPU multiply-add, so the sequential depth is
chunk-length while all batch/feature lanes stay saturated.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel(a_blk, b_blk, h_out, carry, *, chunk: int):
    t_idx = pl.program_id(1)

    @pl.when(t_idx == 0)
    def _init():
        carry[...] = jnp.zeros_like(carry)

    a = a_blk[...].astype(jnp.float32)           # (BB, C, D)
    b = b_blk[...].astype(jnp.float32)
    h = carry[...]                               # (BB, D)
    rows = []
    for t in range(chunk):
        h = a[:, t] * h + b[:, t]
        rows.append(h)
    out = jnp.stack(rows, axis=1)                # (BB, C, D)
    carry[...] = h
    h_out[...] = out.astype(h_out.dtype)


def linear_scan(a: Array, b: Array, *, chunk: int = 32,
                block_b: int = 8, interpret: bool = True) -> Array:
    """h_t = a_t*h_{t-1} + b_t over axis 1. a, b: (B, T, D) -> (B, T, D)."""
    bsz, t, d = a.shape
    bb = min(block_b, bsz)
    c = min(chunk, t)
    pb = (-bsz) % bb
    pt = (-t) % c
    ap = jnp.pad(a, ((0, pb), (0, pt), (0, 0)))
    bp = jnp.pad(b, ((0, pb), (0, pt), (0, 0)))
    bt, tt = ap.shape[0], ap.shape[1]

    out = pl.pallas_call(
        functools.partial(_kernel, chunk=c),
        grid=(bt // bb, tt // c),
        in_specs=[
            pl.BlockSpec((bb, c, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bb, c, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, c, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bt, tt, d), a.dtype),
        scratch_shapes=[pltpu.VMEM((bb, d), jnp.float32)],
        interpret=interpret,
    )(ap, bp)
    return out[:bsz, :t]

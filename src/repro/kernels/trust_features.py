"""Pallas TPU kernel: fused per-client trust FEATURE pass.

One pass over the delivered (M, D) last-layer matrix emitting the four
multi-feature trust signals of ``repro.core.features`` per client:
norm profile vs the selected-median norm, ReLU cosine to the client's
own-cloud reference row, elementwise sign agreement with the selected
aggregate, and the saturating norm-clipped loss-delta proxy.

TPU mapping mirrors ``trust_score.py``: grid over N-blocks × D-blocks
(reduction dim); each step loads a (BN, BD) tile of G and the matching
tile of the per-row reference matrix plus the broadcast (BD,) aggregate
slice, accumulating per-row <g, ref>, ‖g‖², ‖ref‖² and the
sign-agreement count in a (BN, 8) VMEM scratch. The final D-block folds
in the (pre-reduced) median norm and delivery weights and writes the
four feature vectors. Zero-padding of both axes is safe by
construction: padded coordinates contribute 0 to every dot product and
never count as sign agreement, and padded rows carry w = 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel(g_blk, ref_blk, gbar_blk, med_blk, w_blk,
            f0_out, f1_out, f2_out, f3_out, acc,
            *, n_dblocks: int, d_true: int, eps: float):
    d_idx = pl.program_id(1)

    @pl.when(d_idx == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    g = g_blk[...].astype(jnp.float32)              # (BN, BD)
    r = ref_blk[...].astype(jnp.float32)            # (BN, BD)
    gbar = gbar_blk[...].astype(jnp.float32)        # (1, BD)

    acc[:, 0] += jnp.sum(g * r, axis=1)             # <g_i, ref_i>
    acc[:, 1] += jnp.sum(g * g, axis=1)             # ||g_i||²
    acc[:, 2] += jnp.sum(r * r, axis=1)             # ||ref_i||²
    acc[:, 3] += jnp.sum((g * gbar > 0).astype(jnp.float32), axis=1)

    @pl.when(d_idx == n_dblocks - 1)
    def _finalize():
        dot_ref = acc[:, 0]
        norm_g = jnp.sqrt(jnp.maximum(acc[:, 1], 0.0))
        norm_r = jnp.sqrt(jnp.maximum(acc[:, 2], 0.0))
        agree = acc[:, 3]

        med_raw = med_blk[0, 0]
        med = jnp.where(jnp.isnan(med_raw) | ~(med_raw > 0), 1.0, med_raw)
        w = w_blk[...].astype(jnp.float32)

        f0 = 1.0 / (1.0 + jnp.abs(jnp.log(jnp.maximum(norm_g, eps) / med)))
        f1 = jnp.maximum(dot_ref / jnp.maximum(norm_g * norm_r, eps), 0.0)
        f2 = agree / float(d_true)
        ratio = jnp.maximum(norm_g, eps) / med
        x = f1 * jnp.minimum(ratio, 1.0 / ratio)
        f3 = x / (1.0 + x)

        f0_out[...] = f0 * w
        f1_out[...] = f1 * w
        f2_out[...] = f2 * w
        f3_out[...] = f3 * w


def trust_features(grads: Array, refs: Array, gbar: Array, med: Array,
                   w: Array, *, block_n: int = 8, block_d: int = 512,
                   eps: float = 1e-12, interpret: bool = True) -> Array:
    """Fused (M, N_FEATURES) feature pass over (M, D). Pads M and D to
    block multiples; ``med`` is the (possibly NaN) selected-median norm
    and is sanitized in-kernel exactly like the jnp oracle."""
    m, d = grads.shape
    bn = min(block_n, m)
    bd = min(block_d, d)
    pm = (-m) % bn
    pd = (-d) % bd
    g = jnp.pad(grads, ((0, pm), (0, pd)))
    r = jnp.pad(refs, ((0, pm), (0, pd)))
    gb = jnp.pad(gbar, (0, pd))[None, :]
    wp = jnp.pad(w.astype(jnp.float32), (0, pm))
    med_arr = jnp.asarray(med, jnp.float32).reshape(1, 1)
    mm, dd = g.shape
    n_dblocks = dd // bd

    f0, f1, f2, f3 = pl.pallas_call(
        functools.partial(_kernel, n_dblocks=n_dblocks, d_true=d, eps=eps),
        grid=(mm // bn, n_dblocks),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((1, bd), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((mm,), jnp.float32)] * 4,
        scratch_shapes=[pltpu.VMEM((bn, 8), jnp.float32)],
        interpret=interpret,
    )(g, r, gb, med_arr, wp)
    return jnp.stack([f0[:m], f1[:m], f2[:m], f3[:m]], axis=1)

"""Pallas TPU kernel: fused top-k threshold + mask for gradient sparsification.

Input: G (N, D) per-client updates and thr (N, 1) per-row magnitude
thresholds (the k-th largest |g| of each row, computed once on the host
of the grid with ``lax.top_k``). Output: G with every entry whose
magnitude falls below its row threshold zeroed — the dense "decompressed"
form of a top-k sparsified update.

TPU mapping: grid over N-blocks x D-blocks; each step loads a (BN, BD)
VMEM tile of G plus the matching (BN, 1) threshold slice and applies the
compare+select on the VPU. Purely element-wise, so BD=512 (4 lanes of
128) keeps the tile VMEM-resident at any client count.

Tie semantics: |g| == thr entries are KEPT, so rows with ties may retain
more than k entries. Byte accounting in ``repro.compress`` uses the
analytic k, which is exact for continuous-valued gradients (ties have
measure zero).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(g_blk, thr_blk, out_blk):
    g = g_blk[...]                                  # (BN, BD)
    thr = thr_blk[...]                              # (BN, 1) broadcast
    out_blk[...] = jnp.where(jnp.abs(g) >= thr, g, jnp.zeros_like(g))


def topk_mask(grads: Array, thr: Array, *, block_n: int = 8,
              block_d: int = 512, interpret: bool = True) -> Array:
    """Zero every |G[i, d]| < thr[i]. See ref.topk_mask_ref."""
    n, d = grads.shape
    bn = min(block_n, n)
    bd = min(block_d, d)
    pn = (-n) % bn
    pd = (-d) % bd
    g = jnp.pad(grads, ((0, pn), (0, pd)))
    # padded rows threshold at +inf so the pad region stays exactly zero
    t = jnp.pad(thr.reshape(-1, 1).astype(grads.dtype), ((0, pn), (0, 0)),
                constant_values=jnp.inf)
    nn, dd = g.shape

    out = pl.pallas_call(
        _kernel,
        grid=(nn // bn, dd // bd),
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nn, dd), grads.dtype),
        interpret=interpret,
    )(g, t)
    return out[:n, :d]

"""Pure-jnp oracles for every Pallas kernel (the correctness references
used by tests and by interpret-mode validation)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def trust_score_ref(grads: Array, ref: Array, reputation: Array,
                    eps: float = 1e-12) -> Tuple[Array, Array, Array]:
    """Fused Eq. 7 + Eq. 11 statistics over an (N, D) gradient matrix.

    Returns (phi, ts, norms):
      phi_i = ReLU(cos(g_i, ḡ)) * ||g_i||      (ḡ = mean over clients)
      ts_i  = ReLU(cos(g_i, ref)) * r̂_i
      norms_i = ||g_i||
    """
    g = grads.astype(jnp.float32)
    r = ref.astype(jnp.float32)
    gbar = jnp.mean(g, axis=0)
    norms = jnp.linalg.norm(g, axis=1)
    nbar = jnp.linalg.norm(gbar)
    nref = jnp.linalg.norm(r)
    cos_bar = (g @ gbar) / jnp.maximum(norms * nbar, eps)
    cos_ref = (g @ r) / jnp.maximum(norms * nref, eps)
    phi = jax.nn.relu(cos_bar) * norms
    ts = jax.nn.relu(cos_ref) * reputation.astype(jnp.float32)
    return phi, ts, norms


def trust_features_ref(grads: Array, refs: Array, gbar: Array, med: Array,
                       w: Array, eps: float = 1e-12) -> Array:
    """Fused multi-feature trust pass over (M, D): per-row norm profile
    vs the median, ReLU cosine to the per-row reference, sign agreement
    with the aggregate, and the loss-delta proxy — the canonical math
    lives in ``repro.core.features.client_features``."""
    from repro.core.features import client_features
    return client_features(grads, refs, gbar, med, w, eps)


def weighted_agg_ref(grads: Array, ts: Array, norms: Array, ref_norm: Array,
                     eps: float = 1e-12) -> Array:
    """Fused Eq. 12 + Eq. 13: out = Σ_i TS_i·(‖g_ref‖/‖g_i‖)·g_i / Σ_i TS_i."""
    g = grads.astype(jnp.float32)
    w = ts.astype(jnp.float32) * (ref_norm / jnp.maximum(norms, eps))
    out = (w @ g) / jnp.maximum(jnp.sum(ts), eps)
    return out


def topk_mask_ref(grads: Array, thr: Array) -> Array:
    """Dense top-k sparsification: zero |G[i, d]| < thr[i] (ties kept)."""
    t = thr.reshape(-1, 1).astype(grads.dtype)
    return jnp.where(jnp.abs(grads) >= t, grads, jnp.zeros_like(grads))


def stochastic_quantize_ref(x: Array, scale: Array, noise: Array,
                            levels: int, eps: float = 1e-12) -> Array:
    """QSGD stochastic rounding to int32 levels in [-levels, levels]:
    q = sign(x)*floor(|x|/scale*L + u), so E_u[q*scale/L] = x."""
    xf = x.astype(jnp.float32)
    s = jnp.maximum(scale.reshape(-1, 1).astype(jnp.float32), eps)
    v = xf / s * levels
    xi = jnp.minimum(jnp.floor(jnp.abs(v) + noise.astype(jnp.float32)),
                     float(levels))
    return (jnp.sign(v) * xi).astype(jnp.int32)


def dequantize_ref(q: Array, scale: Array, levels: int) -> Array:
    """Inverse of stochastic_quantize_ref: x̂ = q * scale / L."""
    return q.astype(jnp.float32) * scale.reshape(-1, 1) / levels


def linear_scan_ref(a: Array, b: Array) -> Array:
    """h_t = a_t ⊙ h_{t-1} + b_t along axis 1 (h_0 = 0). (B, T, D)."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h

"""Multi-pod dry-run: prove that every (architecture x input-shape x mesh)
combination lowers AND compiles on the production mesh, and extract the
roofline terms from the compiled artifact.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]

Results are cached as JSON per combination so interrupted sweeps resume.
"""
# The VERY FIRST lines, before ANY other import: 512 placeholder devices
# so jax.make_mesh can build the production mesh (jax locks the device
# count on first init). Do NOT replicate this in tests/benches.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (ARCH_IDS, SHAPES, get_arch, shape_applicable)
from repro.configs.base import FLConfig, ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models.frontends import batch_spec
from repro.models.model import Model
from repro.optim import adamw
from repro.roofline.analyze import analyze
from repro.serve.decode import make_prefill_step, make_serve_step
from repro.train.steps import MeshTopology, make_fl_train_step

PARAM_DTYPE = jnp.bfloat16
REF_BATCH_PER_CLOUD = 2


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def train_model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    tokens = shape.global_batch * shape.seq_len
    return 6.0 * cfg.active_param_count() * tokens


def decode_model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    return 2.0 * cfg.active_param_count() * shape.global_batch


def lower_pair(arch: str, shape_name: str, mesh, flcfg: FLConfig
               ) -> Tuple[Any, Any, float]:
    """Returns (lowered, compiled, model_flops)."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    model = Model(cfg)
    params_sds = jax.eval_shape(lambda k: model.init(k, PARAM_DTYPE),
                                jax.random.PRNGKey(0))

    jax.set_mesh(mesh)  # ambient mesh: enables intermediate constraints
    if shape.kind == "train":
        topo = MeshTopology.from_mesh(mesh, flcfg.n_clouds)
        opt = adamw(3e-4)
        opt_sds = jax.eval_shape(opt[0], params_sds)
        step, _ = make_fl_train_step(model, mesh, flcfg, opt)
        batch_sds = batch_spec(cfg, shape)
        ref_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                (topo.n_clouds, REF_BATCH_PER_CLOUD) + s.shape[1:], s.dtype),
            batch_sds)
        rep_sds = jax.ShapeDtypeStruct((topo.n_clients,), jnp.float32)
        args = [params_sds, opt_sds, rep_sds, batch_sds, ref_sds]
        if cfg.fl_strategy == "fused":
            args.append(jax.ShapeDtypeStruct((2,), jnp.uint32))
        lowered = step.lower(*args)
        mf = train_model_flops(cfg, shape)
    elif shape.kind == "prefill":
        step = make_prefill_step(model, mesh, batch=shape.global_batch)
        b_sds = batch_spec(cfg, shape)
        b_sds.pop("labels", None), b_sds.pop("mask", None)
        lowered = step.lower(params_sds, b_sds)
        mf = 2.0 * cfg.active_param_count() * shape.global_batch * shape.seq_len
    else:  # decode
        step, _ = make_serve_step(model, mesh, batch=shape.global_batch,
                                  max_len=shape.seq_len,
                                  cache_dtype=PARAM_DTYPE)
        from repro.models import transformer as tfm
        cache_sds = jax.eval_shape(
            lambda p: tfm.init_cache(p, cfg, shape.global_batch,
                                     shape.seq_len, PARAM_DTYPE),
            params_sds)
        tok_sds = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
        idx_sds = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = step.lower(params_sds, cache_sds, tok_sds, idx_sds)
        mf = decode_model_flops(cfg, shape)
    compiled = lowered.compile()
    return lowered, compiled, mf


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            flcfg: FLConfig, force: bool = False) -> Dict[str, Any]:
    mesh_tag = "pod2x16x16" if multi_pod else "16x16"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    if not shape_applicable(arch, shape_name):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": "skipped", "reason": "see DESIGN.md §4.1"}
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        _, compiled, mf = lower_pair(arch, shape_name, mesh, flcfg)
        report = analyze(compiled, mesh, arch=arch, shape=shape_name,
                         model_flops=mf)
        rec = {"status": "ok", "compile_s": round(time.time() - t0, 1),
               **report.to_json()}
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:],
               "compile_s": round(time.time() - t0, 1)}
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--n-clouds", type=int, default=4)
    args = ap.parse_args()

    flcfg = FLConfig(n_clouds=args.n_clouds, clients_per_round=12)
    pairs = []
    arches = list(ARCH_IDS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in arches:
            for s in shapes:
                pairs.append((a, s, mp))

    for a, s, mp in pairs:
        rec = run_one(a, s, mp, args.out, flcfg, force=args.force)
        status = rec.get("status")
        msg = (f"dominant={rec.get('dominant')} "
               f"compute={rec.get('compute_s', 0):.2e}s "
               f"mem={rec.get('memory_s', 0):.2e}s "
               f"coll={rec.get('collective_s', 0):.2e}s"
               if status == "ok" else rec.get("error", rec.get("reason", "")))
        print(f"[{'2x16x16' if mp else '16x16'}] {a:28s} {s:12s} "
              f"{status:8s} {msg}", flush=True)


if __name__ == "__main__":
    main()

"""Production training launcher.

On real hardware this runs the FL train loop for any --arch on the
production mesh; in this container it is exercised with --debug-mesh
(host devices) and reduced configs. The dry-run path (launch/dryrun.py)
covers the full-scale lower/compile story.

  python -m repro.launch.train --arch gemma2-2b --steps 10 --debug-mesh \
      --smoke
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--strategy", default=None,
                    choices=[None, "two_phase", "fused"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced model config (CPU-sized)")
    ap.add_argument("--debug-mesh", action="store_true",
                    help="use host devices instead of the production mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--n-clouds", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    import os
    if args.debug_mesh:
        os.environ.setdefault("XLA_FLAGS",
                              "--xla_force_host_platform_device_count=8")
    import jax
    import jax.numpy as jnp
    from repro.checkpoint import save_checkpoint
    from repro.configs import SHAPES
    from repro.configs.base import FLConfig
    from repro.launch.mesh import make_debug_mesh, make_production_mesh
    from repro.models import build_model
    from repro.optim import adamw
    from repro.train import make_fl_train_step

    mesh = (make_debug_mesh() if args.debug_mesh
            else make_production_mesh(multi_pod=args.multi_pod))
    jax.set_mesh(mesh)
    model = build_model(args.arch, smoke=args.smoke)
    fl = FLConfig(n_clouds=args.n_clouds, clients_per_round=4)
    opt = adamw(args.lr)
    step, topo = make_fl_train_step(model, mesh, fl, opt,
                                    strategy=args.strategy)
    print(f"mesh={dict(mesh.shape)} clients={topo.n_clients} "
          f"clouds={topo.n_clouds} strategy="
          f"{args.strategy or model.cfg.fl_strategy}")

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt_state = opt[0](params)
    rep = jnp.full((topo.n_clients,), 1.0 / topo.n_clients)
    fused = (args.strategy or model.cfg.fl_strategy) == "fused"

    t0 = time.time()
    for it in range(args.steps):
        kb, kr, key = jax.random.split(key, 3)
        batch = model.dummy_batch(kb, batch=args.batch, seq=args.seq)
        ref = model.dummy_batch(kr, batch=topo.n_clouds * 2, seq=args.seq)
        ref = jax.tree.map(
            lambda x: x.reshape((topo.n_clouds, 2) + x.shape[1:]), ref)
        extra = (jax.random.PRNGKey(it),) if fused else ()
        params, opt_state, rep, met = step(params, opt_state, rep, batch,
                                           ref, *extra)
        print(f"step {it+1:3d} loss={float(met['loss']):.4f} "
              f"rep={np.array2string(np.array(rep), precision=3)} "
              f"({(time.time()-t0)/(it+1):.2f}s/step)", flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": params, "rep": rep},
                        step=args.steps, metadata={"arch": args.arch})
        print("checkpoint ->", args.ckpt)


if __name__ == "__main__":
    main()

"""Serving launcher: batched greedy decoding with a continuous request
queue over the production (or debug) mesh.

  python -m repro.launch.serve --arch gemma2-2b --smoke --debug-mesh \
      --requests 8 --gen 16
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class Request:
    rid: int
    prompt_len: int
    max_new: int
    generated: List[int] = field(default_factory=list)
    done: bool = False


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--debug-mesh", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    import os
    if args.debug_mesh:
        os.environ.setdefault("XLA_FLAGS",
                              "--xla_force_host_platform_device_count=8")
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.launch.mesh import make_debug_mesh
    from repro.models import build_model
    from repro.models import transformer as tfm

    model = build_model(args.arch, smoke=args.smoke)
    cfg = model.cfg
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    max_len = args.prompt_len + args.gen
    b = args.batch

    step = jax.jit(lambda p, c, t, i: tfm.decode_step(p, cfg, c, t, i))

    # simple continuous-batching scheduler: slots hold requests; finished
    # slots are refilled from the queue (static shapes; per-slot indices)
    queue = [Request(i, args.prompt_len, args.gen)
             for i in range(args.requests)]
    slots: List[Optional[Request]] = [None] * b

    # shared-prefix prefill per refill (demo: random prompts)
    def prefill_slot(rng_key):
        batch = model.dummy_batch(rng_key, batch=1, seq=args.prompt_len)
        logits, cache = model.prefill(params, batch, max_len)
        return jnp.argmax(logits, -1)[0], cache

    caches, toks = [None] * b, np.zeros(b, np.int32)
    pos = np.zeros(b, np.int32)
    served = 0
    t0 = time.time()
    steps = 0
    while queue or any(s is not None for s in slots):
        for j in range(b):
            if slots[j] is None and queue:
                slots[j] = queue.pop(0)
                tok, cache = prefill_slot(jax.random.PRNGKey(slots[j].rid))
                caches[j], toks[j] = cache, int(tok)
                pos[j] = args.prompt_len
        for j in range(b):
            r = slots[j]
            if r is None:
                continue
            logits, caches[j] = step(params, caches[j],
                                     jnp.asarray([toks[j]]),
                                     jnp.asarray(int(pos[j])))
            toks[j] = int(jnp.argmax(logits, -1)[0])
            r.generated.append(toks[j])
            pos[j] += 1
            steps += 1
            if len(r.generated) >= r.max_new:
                r.done = True
                served += 1
                print(f"request {r.rid}: {len(r.generated)} tokens "
                      f"-> {r.generated[:8]}...")
                slots[j] = None
    dt = time.time() - t0
    print(f"served {served} requests, {steps} decode steps in {dt:.1f}s "
          f"({steps/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()

"""Production mesh construction. A FUNCTION (not a module constant) so
importing never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 (256 chips) per pod; 2 pods = 512.

    Axes: ``data`` = client cohorts (FL data parallelism), ``model`` =
    tensor/FSDP parallelism, ``pod`` = cloud boundary (multi-pod only).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, multi_pod: bool = False):
    """Small mesh over however many (host) devices exist — used by tests."""
    n = n_devices or len(jax.devices())
    if multi_pod and n >= 8:
        return jax.make_mesh((2, n // 4, 2), ("pod", "data", "model"))
    if n >= 4:
        return jax.make_mesh((n // 2, 2), ("data", "model"))
    return jax.make_mesh((n, 1), ("data", "model"))

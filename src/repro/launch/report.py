"""Render the §Dry-run and §Roofline tables in EXPERIMENTS.md from
results/dryrun/*.json.

  python -m repro.launch.report [--dir results/dryrun] [--write]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.configs import ARCH_IDS, SHAPES

_SHAPE_ORDER = list(SHAPES)


def load(dirname: str) -> List[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def _fmt_s(x) -> str:
    return f"{x:.2e}" if isinstance(x, (int, float)) else "—"


def dryrun_table(recs: List[dict]) -> str:
    rows = ["| arch | shape | mesh | status | compile_s | HBM args+temp/dev | collectives |",
            "|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r.get("arch", ""),
                                         _SHAPE_ORDER.index(r["shape"])
                                         if r.get("shape") in SHAPES else 9,
                                         r.get("mesh", ""))):
        status = r.get("status", "?")
        if status == "ok":
            mem = r.get("memory_per_device_bytes", {})
            hbm = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0)) / 2 ** 30
            coll = ",".join(f"{k}:{v}" for k, v in
                            r.get("collectives_by_kind", {}).items())
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{r.get('compile_s', 0):.0f} | {hbm:.1f} GiB | {coll} |")
        else:
            reason = r.get("reason", r.get("error", ""))[:60]
            rows.append(f"| {r.get('arch')} | {r.get('shape')} | "
                        f"{r.get('mesh')} | {status} | — | — | {reason} |")
    return "\n".join(rows)


def roofline_table(recs: List[dict]) -> str:
    rows = ["| arch | shape | compute_s | memory_s | collective_s | "
            "dominant | useful_flops | x-pod $/step | next lever |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r.get("arch", ""),
                                         _SHAPE_ORDER.index(r["shape"])
                                         if r.get("shape") in SHAPES else 9)):
        if r.get("status") != "ok" or "pod" in r.get("mesh", ""):
            continue
        lever = {
            "memory": "bf16/remat/cache layout or larger per-step compute",
            "compute": "MXU-aligned tiles; fuse elementwise chains",
            "collective": "shard to cut payload; overlap with compute",
        }[r["dominant"]]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} | "
            f"{r['dominant']} | {r.get('useful_flops_ratio', 0):.2f} | "
            f"${r.get('egress_dollars_per_step', 0):.4f} | {lever} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--write", action="store_true",
                    help="splice tables into EXPERIMENTS.md")
    args = ap.parse_args()
    recs = load(args.dir)
    ok = sum(r.get("status") == "ok" for r in recs)
    skip = sum(r.get("status") == "skipped" for r in recs)
    err = sum(r.get("status") == "error" for r in recs)
    summary = (f"{len(recs)} combinations: {ok} ok, {skip} skipped "
               f"(DESIGN.md §4.1), {err} errors")
    dt = dryrun_table(recs)
    rt = roofline_table(recs)
    print(summary)
    print(dt)
    print()
    print(rt)
    if args.write:
        with open("EXPERIMENTS.md") as f:
            text = f.read()
        text = text.replace("<!-- DRYRUN_TABLE -->",
                            f"{summary}\n\n{dt}")
        text = text.replace("<!-- ROOFLINE_TABLE -->", rt)
        with open("EXPERIMENTS.md", "w") as f:
            f.write(text)
        print("\nEXPERIMENTS.md updated")


if __name__ == "__main__":
    main()

from repro.models.model import Model, build_model
from repro.models.frontends import batch_spec, make_batch

__all__ = ["Model", "build_model", "batch_spec", "make_batch"]

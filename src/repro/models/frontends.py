"""Modality frontend STUBS (the one sanctioned carve-out, see DESIGN.md):
``input_specs`` supplies precomputed patch/frame embeddings of the right
shape instead of running a ViT/conv-codec. Concrete embedding generators
exist for the CPU examples/tests."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

Array = jax.Array


def batch_spec(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16
               ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a *training or
    prefill* batch (no device allocation). Text length shrinks by the
    vision-prefix so total sequence = shape.seq_len."""
    b, s = shape.global_batch, shape.seq_len
    text = s - cfg.vis_tokens if cfg.vis_tokens else s
    spec: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct((b, text), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, text), jnp.int32),
        "mask": jax.ShapeDtypeStruct((b, text), jnp.float32),
    }
    if cfg.vis_tokens:
        spec["patches"] = jax.ShapeDtypeStruct((b, cfg.vis_tokens,
                                                cfg.d_model), dtype)
    if cfg.is_encdec:
        spec["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_frames,
                                               cfg.d_model), dtype)
    return spec


def make_batch(key: Array, cfg: ModelConfig, batch: int, seq: int,
               dtype=jnp.float32) -> Dict[str, Array]:
    """Concrete random batch matching ``batch_spec`` (for CPU smoke runs)."""
    k1, k2, k3 = jax.random.split(key, 3)
    text = seq - cfg.vis_tokens if cfg.vis_tokens else seq
    tokens = jax.random.randint(k1, (batch, text), 0, cfg.vocab_size)
    labels = jnp.concatenate([tokens[:, 1:], jnp.zeros_like(tokens[:, :1])],
                             axis=1)
    mask = jnp.ones((batch, text), jnp.float32).at[:, -1].set(0.0)
    out = {"tokens": tokens, "labels": labels, "mask": mask}
    if cfg.vis_tokens:
        out["patches"] = 0.02 * jax.random.normal(
            k2, (batch, cfg.vis_tokens, cfg.d_model), dtype)
    if cfg.is_encdec:
        out["frames"] = 0.02 * jax.random.normal(
            k3, (batch, cfg.enc_frames, cfg.d_model), dtype)
    return out

"""Mixture-of-Experts FFN with top-k routing and capacity-bounded
gather/scatter dispatch (no (T, E, C) one-hot tensor is ever built).

Dispatch: for every expert, take the top-C tokens by routing weight
(vmapped ``lax.top_k`` over the expert axis), gather them, run a batched
(E, C, D) x (E, D, F) einsum, and scatter-add the weighted outputs back.
Static shapes throughout; experts stack on the leading axis so the expert
dim shards over the ``model`` mesh axis (expert parallelism) when E
divides it, falling back to d_ff tensor parallelism otherwise.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init

Array = jax.Array


def _model_axis_size() -> int:
    """Size of the ambient mesh's `model` axis (1 if no mesh)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and "model" in mesh.axis_names:
            return int(mesh.shape["model"])
    except Exception:
        pass
    return 1


def init_moe(key: Array, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Array]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    glu = cfg.ffn_act in ("swiglu", "geglu")
    p = {"router": dense_init(ks[0], (d, e), scale=0.02, dtype=dtype),
         "w_up": dense_init(ks[1], (e, d, f), dtype=dtype),
         "w_down": dense_init(ks[2], (e, f, d), dtype=dtype)}
    if glu:
        p["w_gate"] = dense_init(ks[3], (e, d, f), dtype=dtype)
    return p


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return min(n_tokens, max(8, cap))


def moe_forward(params, x: Array, cfg: ModelConfig
                ) -> Tuple[Array, Array]:
    """x: (B, T, D) -> (out, aux_loss). Tokens flattened to N = B*T."""
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(b * t, d)
    n = xt.shape[0]
    cap = moe_capacity(cfg, n)

    logits = (xt @ params["router"]).astype(jnp.float32)        # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                      # (N, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # per-token-per-expert combined weight (N, E); zero if not routed
    combine = jnp.zeros((n, e), jnp.float32)
    combine = jax.vmap(lambda c, idx, p: c.at[idx].add(p))(combine, top_e,
                                                           top_p)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    frac_tokens = jnp.mean((combine > 0).astype(jnp.float32), axis=0)
    frac_prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_prob) * cfg.router_aux_weight

    # capacity selection: per expert, top-C tokens by weight
    from repro.sharding.constrain import constrain
    w_e = combine.T                                             # (E, N)
    gate_ec, idx_ec = jax.lax.top_k(w_e, cap)                   # (E, C)
    # expert-parallel dispatch: (E, C, D) sharded on experts when E
    # divides the model axis (llama4: 128), else capacity-sharded over the
    # data axes (mixtral: 8 experts -> tensor-parallel d_ff inside the
    # expert). Indices are constrained BEFORE the gather and kept 2-D so
    # the gather/scatter never materialize an unsharded (E*C, D) tensor
    # (21 GB/device for mixtral otherwise — EXPERIMENTS.md §Perf).
    idx_ec = constrain(idx_ec, {0: "model", 1: ("pod", "data")})
    gate_ec = constrain(gate_ec, {0: "model", 1: ("pod", "data")})
    x_ec = jnp.take(xt, idx_ec, axis=0)                         # (E, C, D)
    x_ec = constrain(x_ec, {0: "model", 1: ("pod", "data")})

    if "w_gate" in params:
        act = jax.nn.silu if cfg.ffn_act == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", x_ec, params["w_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", x_ec, params["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x_ec, params["w_up"]))
    if e % _model_axis_size() == 0:
        h = constrain(h, {0: "model", 1: ("pod", "data")})
    else:
        h = constrain(h, {1: ("pod", "data"), 2: "model"})
    y_ec = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    y_ec = constrain(y_ec, {0: "model", 1: ("pod", "data")})
    y_ec = y_ec * gate_ec[..., None].astype(y_ec.dtype)

    out = jnp.zeros((n, d), y_ec.dtype).at[idx_ec].add(y_ec)
    out = constrain(out, {0: ("pod", "data")})
    return out.reshape(b, t, d), aux

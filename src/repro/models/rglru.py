"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block: x -> [gate branch: GeLU(W_gate x)] * [recurrent branch:
W_in x -> causal conv1d(width w) -> RG-LRU] -> W_out.

RG-LRU (per channel):
  r_t = sigmoid(W_a x_t)            recurrence gate
  i_t = sigmoid(W_i x_t)            input gate
  log a_t = -c * softplus(Lambda) * r_t          (c = 8)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The diagonal linear recurrence runs as ``jax.lax.associative_scan``
(TPU-native log-depth parallel scan, see DESIGN.md §2.2); decode is a
single-step state update. ``repro.kernels.linear_scan`` provides the
Pallas kernel variant for the same recurrence.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init

Array = jax.Array
_C = 8.0


def init_rglru(key: Array, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Array]:
    d = cfg.d_model
    rd = cfg.rg_lru_dim or d
    ks = jax.random.split(key, 7)
    # Lambda init so that a spans ~(0.9, 0.999) at r=1 (Griffin appendix)
    u = jax.random.uniform(ks[0], (rd,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))       # softplus^-1(-log u / c)
    return {
        "w_in": dense_init(ks[1], (d, rd), dtype=dtype),
        "w_gate": dense_init(ks[2], (d, rd), dtype=dtype),
        "w_out": dense_init(ks[3], (rd, d), dtype=dtype),
        "w_a": dense_init(ks[4], (rd, rd), scale=0.02, dtype=dtype),
        "w_i": dense_init(ks[5], (rd, rd), scale=0.02, dtype=dtype),
        "conv_w": dense_init(ks[6], (cfg.conv1d_width, rd), scale=0.02,
                             dtype=dtype),
        "lambda": lam.astype(dtype),
    }


def _causal_conv1d(x: Array, w: Array, state: Array | None = None
                   ) -> Array:
    """Depthwise causal conv. x: (B, T, C), w: (W, C).
    ``state``: (B, W-1, C) trailing context for decode continuity."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(x[:, : width - 1])
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(width))
    return out


def _gates(params, u: Array) -> Tuple[Array, Array]:
    r = jax.nn.sigmoid(u @ params["w_a"])
    i = jax.nn.sigmoid(u @ params["w_i"])
    log_a = -_C * jax.nn.softplus(params["lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a).astype(u.dtype)
    gated = (jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12, 1.0)) * i * u)
    return a, gated


def rglru_scan(a: Array, b: Array, h0: Array | None = None) -> Array:
    """h_t = a_t * h_{t-1} + b_t along axis 1 via associative scan."""
    if h0 is not None:
        # fold the carried state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_forward(params, x: Array, cfg: ModelConfig,
                  use_kernel: bool = False) -> Array:
    """Full-sequence forward. x: (B, T, D)."""
    gate = jax.nn.gelu(x @ params["w_gate"])
    u = x @ params["w_in"]
    u = _causal_conv1d(u, params["conv_w"])
    a, b = _gates(params, u)
    if use_kernel:
        from repro.kernels.ops import linear_scan as pl_scan
        h = pl_scan(a, b)
    else:
        h = rglru_scan(a, b)
    return (h * gate) @ params["w_out"]


def init_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32
                     ) -> Dict[str, Array]:
    rd = cfg.rg_lru_dim or cfg.d_model
    return {"h": jnp.zeros((batch, rd), dtype),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, rd), dtype)}


def rglru_decode(params, x: Array, state: Dict[str, Array], cfg: ModelConfig
                 ) -> Tuple[Array, Dict[str, Array]]:
    """One-token decode. x: (B, 1, D)."""
    gate = jax.nn.gelu(x @ params["w_gate"])
    u = x @ params["w_in"]                                   # (B, 1, rd)
    conv_in = jnp.concatenate([state["conv"], u], axis=1)    # (B, W, rd)
    w = params["conv_w"]
    u_c = jnp.einsum("bwc,wc->bc", conv_in, w)[:, None]      # (B, 1, rd)
    a, b = _gates(params, u_c)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = (h[:, None] * gate) @ params["w_out"]
    return y, {"h": h, "conv": conv_in[:, 1:]}

"""RWKV-6 "Finch" time-mix (arXiv:2404.05892) with data-dependent decay.

Per head (head_dim n): state S in R^{n x n} accumulating decayed k (x) v
outer products:

  S_t = diag(w_t) S_{t-1} + k_t v_t^T
  o_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t

with per-channel data-dependent decay
  w_t = exp(-exp(w0 + tanh(x_t W_w1) W_w2))  in (0, 1)

and token-shift mixing for the r/k/v/w projections. Full-sequence mode
runs ``lax.scan`` over time carrying S (the HLO loop the dry-run sees);
decode is the single-step update. This is the chunk-free reference; the
Pallas ``linear_scan`` kernel accelerates diagonal recurrences
(RG-LRU); the dense-state RWKV scan stays in XLA.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init
from repro.models.mlp import _token_shift

Array = jax.Array
_DECAY_LORA = 64


def init_rwkv6(key: Array, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Array]:
    d = cfg.d_model
    ks = jax.random.split(key, 9)
    return {
        "w_r": dense_init(ks[0], (d, d), dtype=dtype),
        "w_k": dense_init(ks[1], (d, d), dtype=dtype),
        "w_v": dense_init(ks[2], (d, d), dtype=dtype),
        "w_o": dense_init(ks[3], (d, d), dtype=dtype),
        "w_decay1": dense_init(ks[4], (d, _DECAY_LORA), scale=0.02, dtype=dtype),
        "w_decay2": dense_init(ks[5], (_DECAY_LORA, d), scale=0.02, dtype=dtype),
        "w0": jnp.full((d,), -5.0, dtype),     # exp(-exp(-5)) ~ slow decay
        "u": dense_init(ks[6], (d,), scale=1.0, dtype=dtype),  # bonus
        "mu": jnp.full((4, d), 0.5, dtype),    # token-shift mixes (r,k,v,w)
    }


def _projections(params, x: Array, shifted: Array):
    mu = params["mu"]
    def mix(i):
        return x * mu[i] + shifted * (1.0 - mu[i])
    r = mix(0) @ params["w_r"]
    k = mix(1) @ params["w_k"]
    v = mix(2) @ params["w_v"]
    dec = jnp.tanh(mix(3) @ params["w_decay1"]) @ params["w_decay2"]
    log_w = -jnp.exp(jnp.clip(params["w0"].astype(jnp.float32)
                              + dec.astype(jnp.float32), -8.0, 4.0))
    w = jnp.exp(log_w).astype(x.dtype)                    # in (0,1)
    return r, k, v, w


def _split_heads(x: Array, n_heads: int) -> Array:
    b, t, d = x.shape
    return x.reshape(b, t, n_heads, d // n_heads)


_SCAN_CHUNK = 64


def rwkv6_forward(params, x: Array, cfg: ModelConfig) -> Array:
    """x: (B, T, D) full-sequence (train / prefill).

    Two-level scan: the outer scan carries the state across
    ``_SCAN_CHUNK``-sized chunks (these boundary states are the only
    residuals the backward saves); the inner per-token scan is rematted,
    so training memory is O(T / chunk) states instead of O(T)."""
    b, t, d = x.shape
    h = cfg.n_heads
    n = d // h
    r, k, v, w = _projections(params, x, _token_shift(x))
    u = params["u"].reshape(h, n)
    r, k, v, w = (_split_heads(a, h) for a in (r, k, v, w))   # (B,T,H,n)

    def step(S, inp):
        rt, kt, vt, wt = inp                                   # (B,H,n)
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)               # (B,H,n,n)
        out = jnp.einsum("bhij,bhi->bhj", S + u[None, :, :, None] * kv, rt)
        S_new = wt[..., None] * S + kv
        return S_new, out

    c = min(_SCAN_CHUNK, t)
    pad = (-t) % c
    xs = tuple(jnp.moveaxis(jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0))),
                            1, 0) for a in (r, k, v, w))       # (T̃,B,H,n)
    n_chunks = (t + pad) // c
    xs = tuple(a.reshape((n_chunks, c) + a.shape[1:]) for a in xs)

    @jax.checkpoint
    def chunk_step(S, chunk_xs):
        return jax.lax.scan(step, S, chunk_xs)

    S0 = jnp.zeros((b, h, n, n), x.dtype)
    _, outs = jax.lax.scan(chunk_step, S0, xs)                 # (nc,c,B,H,n)
    out = jnp.moveaxis(outs.reshape((n_chunks * c,) + outs.shape[2:]),
                       0, 1)[:, :t].reshape(b, t, d)
    return out @ params["w_o"]


def init_rwkv6_state(cfg: ModelConfig, batch: int, dtype=jnp.float32
                     ) -> Dict[str, Array]:
    h = cfg.n_heads
    n = cfg.d_model // h
    return {"S": jnp.zeros((batch, h, n, n), dtype),
            "prev": jnp.zeros((batch, cfg.d_model), dtype)}


def rwkv6_decode(params, x: Array, state: Dict[str, Array], cfg: ModelConfig
                 ) -> Tuple[Array, Dict[str, Array]]:
    """One-token decode. x: (B, 1, D)."""
    b, _, d = x.shape
    h = cfg.n_heads
    n = d // h
    r, k, v, w = _projections(params, x, state["prev"][:, None, :])
    u = params["u"].reshape(h, n)
    r, k, v, w = (a.reshape(b, h, n) for a in (r, k, v, w))
    S = state["S"]
    kv = jnp.einsum("bhi,bhj->bhij", k, v)
    out = jnp.einsum("bhij,bhi->bhj", S + u[None, :, :, None] * kv, r)
    S_new = w[..., None] * S + kv
    y = out.reshape(b, 1, d) @ params["w_o"]
    return y, {"S": S_new, "prev": x[:, 0]}

"""Per-layer block: pre-norm mixer (attention / RG-LRU / RWKV6) +
pre-norm FFN (dense / MoE / channel-mix), with a unified cache protocol
for decode. Layer type and MoE-ness are static per call site so the
transformer can ``lax.scan`` over homogeneous pattern cycles."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN_BLOCKS, ModelConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.common import dense_init, rms_norm

Array = jax.Array
Params = Dict[str, Any]


def init_layer(key: Array, cfg: ModelConfig, layer_type: str, is_moe: bool,
               dtype=jnp.float32, cross: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Params = {"norm1": jnp.zeros((d,), dtype),
                 "norm2": jnp.zeros((d,), dtype)}
    if layer_type in ATTN_BLOCKS:
        p["mixer"] = attn.init_attn(ks[0], cfg, dtype)
    elif layer_type == "R":
        p["mixer"] = rglru_mod.init_rglru(ks[0], cfg, dtype)
    elif layer_type == "W":
        p["mixer"] = rwkv_mod.init_rwkv6(ks[0], cfg, dtype)
    else:
        raise ValueError(layer_type)
    if layer_type == "W":
        p["ffn"] = mlp_mod.init_channel_mix(ks[1], cfg, dtype)
    elif is_moe:
        p["ffn"] = moe_mod.init_moe(ks[1], cfg, dtype)
    else:
        p["ffn"] = mlp_mod.init_mlp(ks[1], cfg, dtype)
    if cross:
        p["norm_x"] = jnp.zeros((d,), dtype)
        p["cross"] = attn.init_attn(ks[2], cfg, dtype, cross=True)
    return p


def _norm(x: Array, scale: Array, cfg: ModelConfig) -> Array:
    return rms_norm(x, scale, cfg.norm_eps, gemma_style=True)


def layer_forward(p: Params, x: Array, *, cfg: ModelConfig, layer_type: str,
                  is_moe: bool, positions: Optional[Array] = None,
                  prefix_len: int = 0, memory: Optional[Array] = None
                  ) -> Tuple[Array, Array]:
    """Full-sequence layer. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = _norm(x, p["norm1"], cfg)
    if layer_type in ATTN_BLOCKS:
        m = attn.attn_forward(p["mixer"], h, cfg=cfg, layer_type=layer_type,
                              positions=positions, prefix_len=prefix_len)
    elif layer_type == "R":
        m = rglru_mod.rglru_forward(p["mixer"], h, cfg)
    else:
        m = rwkv_mod.rwkv6_forward(p["mixer"], h, cfg)
    x = x + m
    if "cross" in p and memory is not None:
        hx = _norm(x, p["norm_x"], cfg)
        x = x + attn.cross_attn_forward(p["cross"], hx, memory, cfg=cfg)
    h2 = _norm(x, p["norm2"], cfg)
    if layer_type == "W":
        f = mlp_mod.channel_mix_forward(p["ffn"], h2)
    elif is_moe:
        f, aux = moe_mod.moe_forward(p["ffn"], h2, cfg)
    else:
        f = mlp_mod.mlp_forward(p["ffn"], h2, cfg)
    out = x + f
    # sequence-parallel residual: the layer-boundary activation (the tensor
    # the remat/scan machinery saves) lives batch-sharded over the data
    # axes AND sequence-sharded over `model`; GSPMD inserts the
    # Megatron-SP all-gather/reduce-scatter pair around attention/FFN.
    # (with_sharding_constraint is TOTAL: the batch dim must be named or
    # it is forced-replicated — see EXPERIMENTS.md §Perf iter 8)
    from repro.sharding.constrain import constrain
    out = constrain(out, {0: ("pod", "data"), 1: "model"})
    return out, aux


def init_layer_cache(cfg: ModelConfig, layer_type: str, batch: int,
                     max_len: int, dtype=jnp.float32, cross: bool = False
                     ) -> Params:
    c: Params = {}
    if layer_type in ATTN_BLOCKS:
        c["attn"] = attn.init_attn_cache(cfg, layer_type, batch, max_len, dtype)
    elif layer_type == "R":
        c["rec"] = rglru_mod.init_rglru_state(cfg, batch, dtype)
    else:
        c["rec"] = rwkv_mod.init_rwkv6_state(cfg, batch, dtype)
    if layer_type == "W":
        c["ffn_prev"] = jnp.zeros((batch, cfg.d_model), dtype)
    if cross:
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        c["cross"] = {"k": jnp.zeros((batch, cfg.enc_frames, kv, hd), dtype),
                      "v": jnp.zeros((batch, cfg.enc_frames, kv, hd), dtype)}
    return c


def layer_decode(p: Params, x: Array, cache: Params, index: Array, *,
                 cfg: ModelConfig, layer_type: str, is_moe: bool
                 ) -> Tuple[Array, Params]:
    """Single-token decode. x: (B, 1, D)."""
    new_cache = dict(cache)
    h = _norm(x, p["norm1"], cfg)
    if layer_type in ATTN_BLOCKS:
        m, new_cache["attn"] = attn.attn_decode(
            p["mixer"], h, cache["attn"], index, cfg=cfg,
            layer_type=layer_type)
    elif layer_type == "R":
        m, new_cache["rec"] = rglru_mod.rglru_decode(p["mixer"], h,
                                                     cache["rec"], cfg)
    else:
        m, new_cache["rec"] = rwkv_mod.rwkv6_decode(p["mixer"], h,
                                                    cache["rec"], cfg)
    x = x + m
    if "cross" in p:
        hx = _norm(x, p["norm_x"], cfg)
        x = x + attn.cross_attn_decode(p["cross"], hx, cache["cross"], cfg=cfg)
    h2 = _norm(x, p["norm2"], cfg)
    if layer_type == "W":
        f = mlp_mod.channel_mix_forward(p["ffn"], h2,
                                        prev=cache["ffn_prev"])
        new_cache["ffn_prev"] = h2[:, 0]
    elif is_moe:
        f, _ = moe_mod.moe_forward(p["ffn"], h2, cfg)
    else:
        f = mlp_mod.mlp_forward(p["ffn"], h2, cfg)
    return x + f, new_cache

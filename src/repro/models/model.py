"""Public model API: ``Model(cfg)`` bundles init / loss / decode for any
assigned architecture. Everything is functional; ``Model`` only carries
the static config."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_arch, reduced
from repro.models import transformer as tfm
from repro.models.frontends import make_batch

Array = jax.Array
Params = Dict[str, Any]


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- init ---------------------------------------------------------------
    def init(self, key: Array, dtype=jnp.float32) -> Params:
        return tfm.init_params(key, self.cfg, dtype)

    # -- training -----------------------------------------------------------
    def loss(self, params: Params, batch: Dict[str, Array],
             loss_chunk: int = 512) -> Tuple[Array, Dict[str, Array]]:
        return tfm.loss_fn(params, self.cfg, batch, loss_chunk)

    def grad_fn(self, loss_chunk: int = 512):
        return jax.value_and_grad(
            lambda p, b: self.loss(p, b, loss_chunk), has_aux=True)

    # -- serving ------------------------------------------------------------
    def init_cache(self, params: Params, batch: int, max_len: int,
                   dtype=jnp.float32, memory: Optional[Array] = None
                   ) -> Params:
        return tfm.init_cache(params, self.cfg, batch, max_len, dtype,
                              memory=memory)

    def encode(self, params: Params, frames: Array) -> Array:
        return tfm.encode(params, self.cfg, frames)

    def prefill(self, params: Params, batch: Dict[str, Array],
                max_len: int, cache_dtype=jnp.float32
                ) -> Tuple[Array, Params]:
        """Run the full prompt through decode steps to fill a cache.
        Returns (last logits (B, V), cache). Used by tests/examples at
        small scale; production prefill lowers the full-sequence forward."""
        tokens = batch["tokens"]
        b, s = tokens.shape
        memory = None
        if self.cfg.is_encdec:
            memory = self.encode(params, batch["frames"])
        cache = self.init_cache(params, b, max_len, cache_dtype,
                                memory=memory)
        logits = None

        def body(carry, i):
            cache, _ = carry
            logits, cache = tfm.decode_step(params, self.cfg, cache,
                                            tokens[:, i], i)
            return (cache, logits), None

        (cache, logits), _ = jax.lax.scan(
            body, (cache, jnp.zeros((b, self.cfg.vocab_size))),
            jnp.arange(s))
        return logits, cache

    def decode_step(self, params: Params, cache: Params, token: Array,
                    index: Array) -> Tuple[Array, Params]:
        return tfm.decode_step(params, self.cfg, cache, token, index)

    # -- helpers ------------------------------------------------------------
    def dummy_batch(self, key: Array, batch: int, seq: int) -> Dict[str, Array]:
        return make_batch(key, self.cfg, batch, seq)


def build_model(arch: str, smoke: bool = False) -> Model:
    cfg = get_arch(arch)
    if smoke:
        cfg = reduced(cfg)
    return Model(cfg)

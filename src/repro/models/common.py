"""Shared layers/utilities for the model zoo (pure functional, pytree params)."""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Dict[str, Any]


def dense_init(key: Array, shape, scale: Optional[float] = None,
               dtype=jnp.float32) -> Array:
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def rms_norm(x: Array, scale: Array, eps: float = 1e-6,
             gemma_style: bool = True) -> Array:
    """RMSNorm in fp32; ``gemma_style`` uses the (1 + w) convention."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = scale.astype(jnp.float32)
    y = y * (1.0 + w) if gemma_style else y * w
    return y.astype(x.dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def softcap(x: Array, cap: float) -> Array:
    """Gemma-2 soft capping: cap * tanh(x / cap). No-op if cap <= 0."""
    if cap <= 0.0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    if theta <= 0.0:
        return x
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,T,1,hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> Array:
    """Whisper-style fixed sinusoidal embeddings (length, dim)."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(-math.log(10000.0) * jnp.arange(0, dim, 2, dtype=jnp.float32)
                  / dim)
    ang = pos * div
    out = jnp.zeros((length, dim), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return out


def act_fn(name: str):
    return {"gelu": jax.nn.gelu, "silu": jax.nn.silu, "relu": jax.nn.relu,
            "relu2": lambda x: jnp.square(jax.nn.relu(x))}[name]


def chunked_cross_entropy(logits_fn, hidden: Array, labels: Array,
                          mask: Array, *, chunk: int = 512,
                          logit_softcap_val: float = 0.0) -> Array:
    """Memory-efficient LM loss: scan over sequence chunks so the
    (B, S, vocab) logits tensor is never materialized.

    ``logits_fn(h_chunk) -> (B, c, V)``; labels/mask: (B, S).
    Returns mean NLL over masked positions.
    """
    from repro.sharding.constrain import constrain
    b, s, _ = hidden.shape
    # gather the sequence-parallel residual once before chunking (the
    # chunk reshape would otherwise force per-chunk resharding)
    hidden = constrain(hidden, {0: ("pod", "data")})
    chunk = min(chunk, s)
    n_chunks = s // chunk
    rem = s - n_chunks * chunk

    @jax.checkpoint
    def chunk_loss(h, y, m):
        # remat: the backward recomputes this chunk's logits from h (one
        # matmul) instead of the loss scan saving an f32 (B, c, V)
        # residual per chunk
        from repro.sharding.constrain import constrain
        logits = logits_fn(h)
        # keep the (B, c, V) chunk vocab-sharded over the model axis and
        # batch-sharded over the data axes — the single biggest activation
        logits = constrain(logits, {0: ("pod", "data"), 2: "model"})
        logits = softcap(logits, logit_softcap_val).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * m
        return jnp.sum(nll)

    if n_chunks > 0:
        hs = hidden[:, :n_chunks * chunk].reshape(b, n_chunks, chunk, -1)
        ys = labels[:, :n_chunks * chunk].reshape(b, n_chunks, chunk)
        ms = mask[:, :n_chunks * chunk].reshape(b, n_chunks, chunk)

        def body(tot, xs):
            h, y, m = xs
            return tot + chunk_loss(h, y, m), None

        total, _ = jax.lax.scan(
            body, jnp.zeros((), jnp.float32),
            (jnp.moveaxis(hs, 1, 0), jnp.moveaxis(ys, 1, 0),
             jnp.moveaxis(ms, 1, 0)))
    else:
        total = jnp.zeros((), jnp.float32)
    if rem:
        total = total + chunk_loss(hidden[:, -rem:], labels[:, -rem:],
                                   mask[:, -rem:])
    denom = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    return total / denom

"""GQA attention: full / sliding-window / chunked, softcap, RoPE,
q-chunked (flash-style) full-sequence path + position-tagged KV-cache
decode path that covers all three masking disciplines.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import apply_rope, dense_init, softcap

Array = jax.Array
NEG_INF = -1e30


def init_attn(key: Array, cfg: ModelConfig, dtype=jnp.float32,
              cross: bool = False) -> Dict[str, Array]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, h, hd), dtype=dtype),
        "wk": dense_init(k2, (d, kv, hd), dtype=dtype),
        "wv": dense_init(k3, (d, kv, hd), dtype=dtype),
        "wo": dense_init(k4, (h, hd, d), scale=1.0 / math.sqrt(h * hd),
                         dtype=dtype),
    }


def _qkv(params, xq: Array, xkv: Array, cfg: ModelConfig):
    q = jnp.einsum("btd,dhk->bthk", xq, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xkv, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xkv, params["wv"])
    return q, k, v


def _sdpa(q: Array, k: Array, v: Array, mask: Array,
          attn_cap: float) -> Array:
    """q: (B,T,KV,G,hd) k/v: (B,S,KV,hd) mask: broadcastable (B,1,1,T,S).
    Returns (B,T,KV,G,hd)."""
    hd = q.shape[-1]
    scores = jnp.einsum("btkgh,bskh->bkgts", q, k) / math.sqrt(hd)
    scores = softcap(scores.astype(jnp.float32), attn_cap)
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgts,bskh->btkgh", p, v)


def _band_mask(qpos: Array, kpos: Array, layer_type: str, cfg: ModelConfig,
               prefix_len: int = 0) -> Array:
    """(T, S) boolean mask for self-attention given absolute positions."""
    qp, kp = qpos[:, None], kpos[None, :]
    causal = kp <= qp
    if layer_type == "L":
        m = causal & (kp > qp - cfg.window)
    elif layer_type == "C":
        m = causal & (kp // cfg.chunk == qp // cfg.chunk)
    else:
        m = causal
    if prefix_len > 0:
        bidir = (kp < prefix_len) & (qp < prefix_len)
        m = m | bidir
    return m


def attn_forward(params, x: Array, *, cfg: ModelConfig, layer_type: str,
                 positions: Optional[Array] = None, prefix_len: int = 0,
                 q_chunk: int = 1024) -> Array:
    """Full-sequence self-attention (train / prefill).

    Scans over query chunks so the score matrix held live is
    (B, H, q_chunk, S) — flash-style memory footprint without a
    materialized (T, S) map. For "L"/"C" layers, keys are additionally
    dynamic-sliced to the reachable band, so compute is O(T·window)
    rather than O(T²).
    """
    b, t, d = x.shape
    kvh, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    if positions is None:
        positions = jnp.arange(t)
    q, k, v = _qkv(params, x, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = q.reshape(b, t, kvh, g, -1)

    q_chunk = min(q_chunk, t)
    if t % q_chunk:                       # keep it simple: pad to multiple
        pad = q_chunk - t % q_chunk
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        qpos_all = jnp.concatenate([positions, jnp.full((pad,), -1)])
    else:
        pad = 0
        qpos_all = positions
    tq = q.shape[1]
    n_blocks = tq // q_chunk

    # Reachable-key band size for local/chunked layers (static).
    if layer_type == "L":
        band = min(t, cfg.window + q_chunk)
    elif layer_type == "C":
        band = min(t, ((cfg.chunk + q_chunk - 1) // cfg.chunk) * cfg.chunk)
    else:
        band = t

    def block(carry, i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(qpos_all, i * q_chunk, q_chunk)
        if band < t:
            # slice keys to the band ending at this q block's last position
            end = jnp.minimum((i + 1) * q_chunk, t)
            start = jnp.clip(end - band, 0, t - band)
            ki = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            kp = start + jnp.arange(band)
        else:
            ki, vi, kp = k, v, positions
        m = _band_mask(qp, kp, layer_type, cfg, prefix_len)
        m = m & (qp[:, None] >= 0)
        # remat the score/softmax block: backward recomputes the
        # (H, q_chunk, S) score tile instead of saving it — flash-attention
        # memory profile without the kernel
        sdpa = jax.checkpoint(
            lambda q_, k_, v_, m_: _sdpa(q_, k_, v_, m_, cfg.attn_softcap))
        oi = sdpa(qi, ki, vi, m[None, None, None])
        return carry, oi

    _, outs = jax.lax.scan(block, 0, jnp.arange(n_blocks))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, tq, cfg.n_heads, -1)
    if pad:
        out = out[:, :t]
    return jnp.einsum("bthk,hkd->btd", out, params["wo"])


def cross_attn_forward(params, x: Array, memory: Array, *,
                       cfg: ModelConfig) -> Array:
    """Encoder-decoder cross attention (no mask, no rope)."""
    b, t, _ = x.shape
    kvh, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qkv(params, x, memory, cfg)
    q = q.reshape(b, t, kvh, g, -1)
    mask = jnp.ones((1, 1, 1, t, memory.shape[1]), bool)
    out = _sdpa(q, k, v, mask, 0.0).reshape(b, t, cfg.n_heads, -1)
    return jnp.einsum("bthk,hkd->btd", out, params["wo"])


# ---------------------------------------------------------------------------
# Decode path: position-tagged KV cache valid for full / window / chunk.

def cache_len(cfg: ModelConfig, layer_type: str, max_len: int) -> int:
    if layer_type == "L":
        return min(max_len, cfg.window)
    if layer_type == "C":
        return min(max_len, cfg.chunk)
    return max_len


def init_attn_cache(cfg: ModelConfig, layer_type: str, batch: int,
                    max_len: int, dtype=jnp.float32) -> Dict[str, Array]:
    s = cache_len(cfg, layer_type, max_len)
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, s, kv, hd), dtype),
        "v": jnp.zeros((batch, s, kv, hd), dtype),
        "pos": jnp.full((s,), -1, jnp.int32),   # absolute position per slot
    }


def attn_decode(params, x: Array, cache: Dict[str, Array], index: Array, *,
                cfg: ModelConfig, layer_type: str
                ) -> Tuple[Array, Dict[str, Array]]:
    """One-token decode. ``index`` is the scalar absolute position of the
    new token; the cache slot is derived from the layer's masking
    discipline (full: index, window/chunk: index mod cache length)."""
    b = x.shape[0]
    kvh, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    s = cache["k"].shape[1]
    q, k_new, v_new = _qkv(params, x, x, cfg)
    pos = jnp.full((1,), 0) + index
    q = apply_rope(q, pos[None, :], cfg.rope_theta).reshape(b, 1, kvh, g, -1)
    k_new = apply_rope(k_new, pos[None, :], cfg.rope_theta)

    slot = index % s
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((1,), index, jnp.int32), slot, axis=0)

    if layer_type == "L":
        lower = index - cfg.window + 1
    elif layer_type == "C":
        lower = (index // cfg.chunk) * cfg.chunk
    else:
        lower = 0
    valid = (cpos >= lower) & (cpos <= index) & (cpos >= 0)       # (s,)
    out = _decode_attn(q, k, v, valid, cfg.attn_softcap)
    out = out.reshape(b, 1, cfg.n_heads, -1)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, {"k": k, "v": v, "pos": cpos}


# One-token scores are (B,H,1,S) — small even at 500k — while chunked
# dynamic-slices over a sharded cache force SPMD replication. Keep the
# flash path only for huge UNSHARDED caches (single-host serving).
_DECODE_CHUNK = 1 << 20


def _decode_attn(q: Array, k: Array, v: Array, valid: Array,
                 attn_cap: float) -> Array:
    """Flash-style one-token attention over a (possibly huge) cache.

    Scans cache chunks with a running (max, denom, out) triple so the
    live score tensor is (B, KV, G, 1, chunk) instead of (..., S) —
    the memory fix for long_500k decode. q: (B,1,KV,G,hd);
    k/v: (B,S,KV,hd); valid: (S,)."""
    s = k.shape[1]
    if s <= _DECODE_CHUNK:
        return _sdpa(q, k, v, valid[None, None, None, None, :], attn_cap)
    c = _DECODE_CHUNK
    n = (s + c - 1) // c
    pad = n * c - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        valid = jnp.pad(valid, (0, pad))
    b, _, kvh, hd = k.shape
    g = q.shape[3]
    hd_scale = 1.0 / math.sqrt(hd)

    def chunk_step(carry, i):
        m, l, o = carry
        ki = jax.lax.dynamic_slice_in_dim(k, i * c, c, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(v, i * c, c, axis=1)
        vm = jax.lax.dynamic_slice_in_dim(valid, i * c, c)
        sc = jnp.einsum("btkgh,bskh->bkgts", q, ki) * hd_scale
        sc = softcap(sc.astype(jnp.float32), attn_cap)
        sc = jnp.where(vm[None, None, None, None, :], sc, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        o_new = o * corr[..., None] + jnp.einsum(
            "bkgts,bskh->bkgth", p.astype(q.dtype), vi).astype(jnp.float32)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, kvh, g, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, 1), jnp.float32)
    o0 = jnp.zeros((b, kvh, g, 1, hd), jnp.float32)
    (m, l, o), _ = jax.lax.scan(chunk_step, (m0, l0, o0), jnp.arange(n))
    out = o / jnp.maximum(l[..., None], 1e-30)
    # (B,KV,G,1,hd) -> (B,1,KV,G,hd)
    return jnp.moveaxis(out, 3, 1).astype(q.dtype)


def init_cross_cache(params, memory: Array, cfg: ModelConfig) -> Dict[str, Array]:
    """Precompute cross-attention K/V from encoder output."""
    k = jnp.einsum("bsd,dhk->bshk", memory, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, params["wv"])
    return {"k": k, "v": v}


def cross_attn_decode(params, x: Array, cache: Dict[str, Array], *,
                      cfg: ModelConfig) -> Array:
    b = x.shape[0]
    kvh, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"]).reshape(b, 1, kvh, g, -1)
    mask = jnp.ones((1, 1, 1, 1, cache["k"].shape[1]), bool)
    out = _sdpa(q, cache["k"], cache["v"], mask, 0.0).reshape(
        b, 1, cfg.n_heads, -1)
    return jnp.einsum("bthk,hkd->btd", out, params["wo"])

"""Decoder / encoder-decoder transformer assembled from blocks, with
``lax.scan`` over repeated pattern cycles (bounded HLO at 88 layers x 512
devices), stub modality frontends, chunked LM loss, and a decode path.

Layer grouping: the per-layer (block_type, is_moe) signature repeats with
period ``P_eff = lcm(len(block_pattern), moe_every)``. The first
``R = L // P_eff`` cycles scan over stacked params; the remaining
``L % P_eff`` layers run unrolled.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.common import (chunked_cross_entropy, dense_init, rms_norm,
                                 sinusoidal_positions)

Array = jax.Array
Params = Dict[str, Any]


def _p_eff(cfg: ModelConfig) -> int:
    p = len(cfg.block_pattern)
    if cfg.n_experts > 0 and cfg.moe_every > 1:
        p = math.lcm(p, cfg.moe_every)
    return min(p, cfg.num_layers)


def layer_signature(cfg: ModelConfig, layer_idx: int) -> Tuple[str, bool]:
    return cfg.layer_types()[layer_idx], cfg.is_moe_layer(layer_idx)


def _stack(trees: List[Params]) -> Params:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ---------------------------------------------------------------------------
# init

def init_params(key: Array, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, cfg.num_layers + cfg.enc_layers + 4)
    d, v = cfg.d_model, cfg.vocab_size
    p_eff = _p_eff(cfg)
    r = cfg.num_layers // p_eff
    rem = cfg.num_layers % p_eff
    cross = cfg.is_encdec

    per_layer = [
        blocks.init_layer(keys[i], cfg, *layer_signature(cfg, i), dtype=dtype,
                          cross=cross)
        for i in range(cfg.num_layers)
    ]
    scanned = [_stack([per_layer[i * p_eff + j] for i in range(r)])
               for j in range(p_eff)] if r > 0 else []
    tail = per_layer[r * p_eff:]

    params: Params = {
        "embed": dense_init(keys[-1], (v, d), scale=0.02, dtype=dtype),
        "scanned": scanned,
        "tail": tail,
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[-2], (d, v), dtype=dtype)
    if cfg.is_encdec:
        enc_layers = [blocks.init_layer(keys[cfg.num_layers + i], cfg, "A",
                                        False, dtype=dtype)
                      for i in range(cfg.enc_layers)]
        params["encoder"] = {"layers": _stack(enc_layers),
                             "final_norm": jnp.zeros((d,), dtype)}
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)

def _embed(params, cfg: ModelConfig, tokens: Array) -> Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.emb_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def logits_fn(params, cfg: ModelConfig, h: Array) -> Array:
    from repro.sharding.constrain import constrain
    logits = h @ params["embed"].T if cfg.tie_embeddings \
        else h @ params["lm_head"]
    return constrain(logits, {logits.ndim - 1: "model"})


def _run_layers(params, cfg: ModelConfig, x: Array, *, prefix_len: int = 0,
                memory: Optional[Array] = None,
                positions: Optional[Array] = None) -> Tuple[Array, Array]:
    """Apply all decoder layers. Returns (hidden, aux_loss)."""
    p_eff = _p_eff(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    def cycle(x_aux, cycle_params):
        x, aux = x_aux
        for j in range(p_eff):
            lt, moe = layer_signature(cfg, j)
            fwd = partial(blocks.layer_forward, cfg=cfg, layer_type=lt,
                          is_moe=moe, positions=positions,
                          prefix_len=prefix_len, memory=memory)
            if cfg.remat:
                fwd = jax.checkpoint(fwd)
            x, a = fwd(cycle_params[j], x)
            aux = aux + a
        return (x, aux), None

    if params["scanned"]:
        (x, aux_total), _ = jax.lax.scan(cycle, (x, aux_total),
                                         params["scanned"])
    base = (cfg.num_layers // p_eff) * p_eff
    for j, lp in enumerate(params["tail"]):
        lt, moe = layer_signature(cfg, base + j)
        x, a = blocks.layer_forward(lp, x, cfg=cfg, layer_type=lt,
                                    is_moe=moe, positions=positions,
                                    prefix_len=prefix_len, memory=memory)
        aux_total = aux_total + a
    return x, aux_total


def encode(params, cfg: ModelConfig, frames: Array) -> Array:
    """Whisper-style encoder over stub frame embeddings (B, F, D):
    bidirectional attention + sinusoidal positions."""
    enc = params["encoder"]
    f = frames.shape[1]
    x = frames + sinusoidal_positions(f, cfg.d_model).astype(frames.dtype)

    def body(x, lp):
        y, _ = blocks.layer_forward(lp, x, cfg=cfg, layer_type="A",
                                    is_moe=False, prefix_len=f)
        return y, None

    x, _ = jax.lax.scan(body, x, enc["layers"])
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


def forward_hidden(params, cfg: ModelConfig, batch: Dict[str, Array]
                   ) -> Tuple[Array, Array, int]:
    """Embed (+ modality prefix), run layers. Returns
    (hidden (B,S,D), aux_loss, text_offset)."""
    from repro.sharding.constrain import constrain
    tokens = batch["tokens"]
    x = constrain(_embed(params, cfg, tokens), {0: ("pod", "data")})
    prefix_len = 0
    memory = None
    if cfg.vis_tokens > 0 and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        prefix_len = batch["patches"].shape[1]
    if cfg.is_encdec:
        memory = encode(params, cfg, batch["frames"])
    b, s, _ = x.shape
    if cfg.rope_theta <= 0 and cfg.family != "ssm":
        x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
    positions = jnp.arange(s)
    h, aux = _run_layers(params, cfg, x, prefix_len=prefix_len,
                         memory=memory, positions=positions)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, aux, prefix_len


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Array],
            loss_chunk: int = 512) -> Tuple[Array, Dict[str, Array]]:
    """Next-token LM loss (+ MoE aux). ``batch``: tokens (B,S_text),
    optional labels/mask (default: shifted tokens), optional
    patches/frames for VLM/audio."""
    h, aux, off = forward_hidden(params, cfg, batch)
    tokens = batch["tokens"]
    labels = batch.get("labels")
    if labels is None:
        labels = jnp.concatenate([tokens[:, 1:],
                                  jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
        mask = mask.at[:, -1].set(0.0)
    h_text = h[:, off:]                       # drop modality prefix
    lm = chunked_cross_entropy(
        lambda hc: logits_fn(params, cfg, hc), h_text, labels,
        mask.astype(jnp.float32), chunk=loss_chunk,
        logit_softcap_val=cfg.logit_softcap)
    total = lm + aux
    return total, {"lm_loss": lm, "aux_loss": aux}


# ---------------------------------------------------------------------------
# decode

def init_cache(params, cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32, memory: Optional[Array] = None) -> Params:
    p_eff = _p_eff(cfg)
    r = cfg.num_layers // p_eff
    cross = cfg.is_encdec

    per_layer = [
        blocks.init_layer_cache(cfg, cfg.layer_types()[i], batch, max_len,
                                dtype, cross=cross)
        for i in range(cfg.num_layers)
    ]
    if cross and memory is not None:
        # precompute cross-attention K/V per layer
        from repro.models.attention import init_cross_cache
        for i in range(cfg.num_layers):
            lp = _layer_params(params, cfg, i)
            per_layer[i]["cross"] = init_cross_cache(lp["cross"], memory, cfg)
    scanned = [_stack([per_layer[i * p_eff + j] for i in range(r)])
               for j in range(p_eff)] if r > 0 else []
    return {"scanned": scanned, "tail": per_layer[r * p_eff:]}


def _layer_params(params, cfg: ModelConfig, i: int) -> Params:
    p_eff = _p_eff(cfg)
    r = cfg.num_layers // p_eff
    if i < r * p_eff:
        grp = params["scanned"][i % p_eff]
        return jax.tree.map(lambda x: x[i // p_eff], grp)
    return params["tail"][i - r * p_eff]


def decode_step(params, cfg: ModelConfig, cache: Params, token: Array,
                index: Array) -> Tuple[Array, Params]:
    """One decode step. token: (B,) int32; index: scalar absolute position.
    Returns (logits (B, V), new cache)."""
    x = _embed(params, cfg, token[:, None])
    if cfg.rope_theta <= 0 and cfg.family != "ssm":
        # sinusoidal position for the current index
        d = cfg.d_model
        div = jnp.exp(-math.log(10000.0)
                      * jnp.arange(0, d, 2, dtype=jnp.float32) / d)
        ang = index.astype(jnp.float32) * div
        pe = jnp.zeros((d,), jnp.float32).at[0::2].set(jnp.sin(ang))
        pe = pe.at[1::2].set(jnp.cos(ang))
        x = x + pe.astype(x.dtype)
    p_eff = _p_eff(cfg)

    def cycle(x, scanned):
        cycle_params, cycle_cache = scanned
        new_caches = []
        for j in range(p_eff):
            lt, moe = layer_signature(cfg, j)
            x, nc = blocks.layer_decode(cycle_params[j], x, cycle_cache[j],
                                        index, cfg=cfg, layer_type=lt,
                                        is_moe=moe)
            new_caches.append(nc)
        return x, new_caches

    new_cache: Params = {"scanned": [], "tail": []}
    if params["scanned"]:
        def body(x, pc):
            return cycle(x, pc)
        x, upd = jax.lax.scan(body, x, (params["scanned"],
                                        cache["scanned"]))
        new_cache["scanned"] = upd
    base = (cfg.num_layers // p_eff) * p_eff
    for j, (lp, lc) in enumerate(zip(params["tail"], cache["tail"])):
        lt, moe = layer_signature(cfg, base + j)
        x, nc = blocks.layer_decode(lp, x, lc, index, cfg=cfg,
                                    layer_type=lt, is_moe=moe)
        new_cache["tail"].append(nc)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, cfg, h)[:, 0]
    if cfg.logit_softcap > 0:
        from repro.models.common import softcap
        logits = softcap(logits, cfg.logit_softcap)
    return logits, new_cache

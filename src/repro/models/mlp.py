"""Dense FFN variants: SwiGLU / GeGLU / plain GELU, plus the RWKV
channel-mix used by "W" layers."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init

Array = jax.Array


def init_mlp(key: Array, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Array]:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.ffn_act in ("swiglu", "geglu"):
        return {"w_gate": dense_init(ks[0], (d, f), dtype=dtype),
                "w_up": dense_init(ks[1], (d, f), dtype=dtype),
                "w_down": dense_init(ks[2], (f, d), dtype=dtype)}
    return {"w_up": dense_init(ks[0], (d, f), dtype=dtype),
            "w_down": dense_init(ks[1], (f, d), dtype=dtype)}


def mlp_forward(params, x: Array, cfg: ModelConfig) -> Array:
    if cfg.ffn_act in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.ffn_act == "swiglu" else jax.nn.gelu
        h = act(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]


def init_channel_mix(key: Array, cfg: ModelConfig, dtype=jnp.float32
                     ) -> Dict[str, Array]:
    """RWKV channel mix: squared-ReLU key path with a receptance gate."""
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {"w_k": dense_init(ks[0], (d, f), dtype=dtype),
            "w_v": dense_init(ks[1], (f, d), dtype=dtype),
            "w_r": dense_init(ks[2], (d, d), dtype=dtype),
            "mu_k": jnp.full((d,), 0.5, dtype),
            "mu_r": jnp.full((d,), 0.5, dtype)}


def _token_shift(x: Array, prev: Array | None = None) -> Array:
    """RWKV token shift: previous timestep's activations (zeros/``prev``
    for t=0). x: (B, T, D)."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def channel_mix_forward(params, x: Array, prev: Array | None = None) -> Array:
    xs = _token_shift(x, prev)
    xk = x * params["mu_k"] + xs * (1.0 - params["mu_k"])
    xr = x * params["mu_r"] + xs * (1.0 - params["mu_r"])
    k = jnp.square(jax.nn.relu(xk @ params["w_k"]))
    return jax.nn.sigmoid(xr @ params["w_r"]) * (k @ params["w_v"])

"""Optimizers (pytree-native, optax-style pure functions): SGD(+momentum),
AdamW with fp32 master accounting, global-norm clipping, LR schedules."""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Any


class OptState(NamedTuple):
    step: Array
    mu: Params            # momentum / first moment (None-like zeros)
    nu: Optional[Params]  # second moment (adamw only)


def _zeros_like_f32(t):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)


def clip_by_global_norm(grads: Params, max_norm: float) -> Tuple[Params, Array]:
    sq = jax.tree.reduce(
        jnp.add, jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2),
                              grads))
    gnorm = jnp.sqrt(jnp.maximum(sq, 1e-20))
    scale = jnp.minimum(1.0, max_norm / gnorm)
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def sgd(lr: float | Callable[[Array], Array], momentum: float = 0.0):
    def init(params: Params) -> OptState:
        mu = _zeros_like_f32(params) if momentum else None
        return OptState(jnp.zeros((), jnp.int32), mu, None)

    def update(grads: Params, state: OptState, params: Params
               ) -> Tuple[Params, OptState]:
        lr_t = lr(state.step) if callable(lr) else lr
        if momentum:
            mu = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state.mu, grads)
            upd = jax.tree.map(lambda m, p: (-lr_t * m).astype(p.dtype),
                               mu, params)
        else:
            mu = None
            upd = jax.tree.map(lambda g, p: (-lr_t * g).astype(p.dtype),
                               grads, params)
        new = jax.tree.map(jnp.add, params, upd)
        return new, OptState(state.step + 1, mu, None)

    return init, update


def adamw(lr: float | Callable[[Array], Array], b1: float = 0.9,
          b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.0):
    def init(params: Params) -> OptState:
        return OptState(jnp.zeros((), jnp.int32), _zeros_like_f32(params),
                        _zeros_like_f32(params))

    def update(grads: Params, state: OptState, params: Params
               ) -> Tuple[Params, OptState]:
        step = state.step + 1
        lr_t = lr(step) if callable(lr) else lr
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            step_val = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(
                jnp.float32)
            return (p.astype(jnp.float32) - lr_t * step_val).astype(p.dtype)

        new = jax.tree.map(upd, params, mu, nu)
        return new, OptState(step, mu, nu)

    return init, update


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable[[Array], Array]:
    def sched(step: Array) -> Array:
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return sched


OPTIMIZERS = {"sgd": sgd, "adamw": adamw}

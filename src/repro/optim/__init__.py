from repro.optim.optimizers import (OPTIMIZERS, OptState, adamw,
                                    clip_by_global_norm, cosine_schedule, sgd)

__all__ = ["OPTIMIZERS", "OptState", "adamw", "clip_by_global_norm",
           "cosine_schedule", "sgd"]

"""Byzantine-robust aggregation baselines the paper compares against:
FedAvg [1], Krum / Multi-Krum [6], coordinate-wise Trimmed-Mean and
Median [7], and FLTrust [8]. All take an (N, D) update matrix (rows =
clients) and return a (D,) aggregate; jittable.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

Array = jax.Array


def fedavg(updates: Array, weights: Array | None = None) -> Array:
    """Weighted mean (weights default to uniform; the paper weights by
    |D_i|/|D| — pass data sizes as ``weights``)."""
    g = updates.reshape(updates.shape[0], -1)
    if weights is None:
        out = jnp.mean(g, axis=0)
    else:
        w = weights / jnp.maximum(jnp.sum(weights), 1e-12)
        out = w @ g
    return out.reshape(updates.shape[1:])


def krum(updates: Array, n_malicious: int, multi: int = 1) -> Array:
    """(Multi-)Krum: score_i = Σ of squared distances to the n−f−2 nearest
    neighbours; select the ``multi`` lowest-scoring updates and average."""
    g = updates.reshape(updates.shape[0], -1)
    n = g.shape[0]
    sq = jnp.sum(g * g, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (g @ g.T)          # (N, N)
    d2 = d2 + jnp.eye(n) * 1e30                               # exclude self
    k = max(1, n - n_malicious - 2)
    nearest = -jax.lax.top_k(-d2, k)[0]                       # k smallest per row
    scores = jnp.sum(nearest, axis=1)
    _, sel = jax.lax.top_k(-scores, max(1, multi))
    return jnp.mean(g[sel], axis=0).reshape(updates.shape[1:])


def trimmed_mean(updates: Array, trim_frac: float = 0.1) -> Array:
    """Coordinate-wise trimmed mean: drop the ``trim`` largest and smallest
    values per coordinate."""
    g = updates.reshape(updates.shape[0], -1)
    n = g.shape[0]
    trim = int(n * trim_frac)
    s = jnp.sort(g, axis=0)
    kept = s[trim:n - trim] if trim > 0 else s
    return jnp.mean(kept, axis=0).reshape(updates.shape[1:])


def coordinate_median(updates: Array) -> Array:
    g = updates.reshape(updates.shape[0], -1)
    return jnp.median(g, axis=0).reshape(updates.shape[1:])


def fltrust(updates: Array, ref_update: Array, eps: float = 1e-12) -> Array:
    """FLTrust [8]: TS_i = ReLU(cos(g_i, g_ref)); updates rescaled to the
    reference norm; trust-weighted average. (Cost-TrustFL extends this
    with the reputation factor — see repro.core.trust.)"""
    g = updates.reshape(updates.shape[0], -1)
    ref = ref_update.reshape(-1)
    refn = jnp.linalg.norm(ref)
    norms = jnp.linalg.norm(g, axis=1)
    cos = (g @ ref) / jnp.maximum(norms * refn, eps)
    ts = jax.nn.relu(cos)
    g_tilde = g * (refn / jnp.maximum(norms, eps))[:, None]
    out = (ts @ g_tilde) / jnp.maximum(jnp.sum(ts), eps)
    return out.reshape(updates.shape[1:])


AGGREGATORS = {
    "fedavg": lambda u, ctx: fedavg(u, ctx.get("weights")),
    "krum": lambda u, ctx: krum(u, ctx.get("n_malicious", 0),
                                ctx.get("multi", 1)),
    "trimmed_mean": lambda u, ctx: trimmed_mean(u, ctx.get("trim_frac", 0.1)),
    "median": lambda u, ctx: coordinate_median(u),
    "fltrust": lambda u, ctx: fltrust(u, ctx["ref_update"]),
}

"""Shapley-value contribution evaluation (paper §IV-B, Fig. 5).

Three estimators:

* ``gradient_contribution`` — the paper's O(N) lightweight score (Eq. 7):
  ``φ_i = ReLU(cos(g_i^(L), ḡ^(L))) · ‖g_i^(L)‖₂`` over last-layer grads.
* ``exact_shapley`` — O(2^N) enumeration for ground truth on tiny N.
* ``monte_carlo_shapley`` — permutation-sampling baseline (Data Shapley).

The latter two exist to reproduce Fig. 5 (time + Pearson correlation) and
to validate the approximation in tests.
"""
from __future__ import annotations

import itertools
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _flat(g: Array) -> Array:
    return g.reshape(g.shape[0], -1) if g.ndim > 1 else g[:, None]


def gradient_contribution(last_layer_grads: Array,
                          mean_grad: Optional[Array] = None,
                          eps: float = 1e-12) -> Array:
    """Eq. 7: φ_i = ReLU(cos(g_i, ḡ)) · ‖g_i‖₂.

    Args:
      last_layer_grads: (N, D) per-client last-layer gradients (flattened).
      mean_grad: optional (D,) ḡ; defaults to the mean over clients.
    Returns: (N,) non-negative contribution scores.
    """
    g = _flat(last_layer_grads)
    gbar = jnp.mean(g, axis=0) if mean_grad is None else mean_grad.reshape(-1)
    dots = g @ gbar                                  # (N,)
    norms = jnp.linalg.norm(g, axis=1)               # (N,)
    nbar = jnp.linalg.norm(gbar)
    cos = dots / jnp.maximum(norms * nbar, eps)
    return jax.nn.relu(cos) * norms


def exact_shapley(utility: Callable[[np.ndarray], float], n: int) -> np.ndarray:
    """Exact Shapley values by subset enumeration. ``utility`` maps a
    boolean mask (n,) -> scalar coalition utility. O(2^n) — tiny n only."""
    assert n <= 16, "exact enumeration is exponential; use n<=16"
    phi = np.zeros(n)
    fact = math.factorial
    denom = fact(n)
    # cache utilities per subset bitmask
    util = {}
    for bits in range(1 << n):
        mask = np.array([(bits >> j) & 1 for j in range(n)], bool)
        util[bits] = float(utility(mask))
    for i in range(n):
        for bits in range(1 << n):
            if (bits >> i) & 1:
                continue
            s = bin(bits).count("1")
            w = fact(s) * fact(n - s - 1) / denom
            phi[i] += w * (util[bits | (1 << i)] - util[bits])
    return phi


def monte_carlo_shapley(utility: Callable[[np.ndarray], float], n: int,
                        n_perms: int = 200, seed: int = 0) -> np.ndarray:
    """Permutation-sampling Shapley (Ghorbani & Zou 2019)."""
    rng = np.random.default_rng(seed)
    phi = np.zeros(n)
    for _ in range(n_perms):
        perm = rng.permutation(n)
        mask = np.zeros(n, bool)
        prev = float(utility(mask))
        for i in perm:
            mask[i] = True
            cur = float(utility(mask))
            phi[i] += cur - prev
            prev = cur
    return phi / n_perms


def cosine_utility(last_layer_grads: np.ndarray,
                   reference: np.ndarray) -> Callable[[np.ndarray], float]:
    """Coalition utility used for validation: alignment of the coalition's
    mean gradient with a reference direction (a standard proxy for the
    coalition's marginal loss improvement under one SGD step)."""
    g = np.asarray(last_layer_grads, np.float64).reshape(last_layer_grads.shape[0], -1)
    ref = np.asarray(reference, np.float64).reshape(-1)
    refn = np.linalg.norm(ref) + 1e-12

    def utility(mask: np.ndarray) -> float:
        if not mask.any():
            return 0.0
        gm = g[mask].mean(axis=0)
        return float(gm @ ref) / refn
    return utility

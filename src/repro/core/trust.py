"""FLTrust-style Byzantine-robust trust scoring + aggregation (Eq. 11–13).

Operates on flattened gradient matrices; the production train step calls
the same functions on pytrees via the helpers at the bottom.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def trust_scores(last_layer_grads: Array, ref_last_layer: Array,
                 reputation: Array, eps: float = 1e-12) -> Array:
    """Eq. 11: TS_i = ReLU(cos(g_i^(L), g_ref^(L))) · r̂_i."""
    g = last_layer_grads.reshape(last_layer_grads.shape[0], -1)
    ref = ref_last_layer.reshape(-1)
    dots = g @ ref
    cos = dots / jnp.maximum(jnp.linalg.norm(g, axis=1) * jnp.linalg.norm(ref), eps)
    return jax.nn.relu(cos) * reputation


def normalize_updates(grads: Array, ref_grad: Array, eps: float = 1e-12) -> Array:
    """Eq. 12: g̃_i = (‖g_ref‖₂ / ‖g_i‖₂) · g_i  (rows of (N, D))."""
    g = grads.reshape(grads.shape[0], -1)
    norms = jnp.linalg.norm(g, axis=1, keepdims=True)
    refn = jnp.linalg.norm(ref_grad.reshape(-1))
    return (g * (refn / jnp.maximum(norms, eps))).reshape(grads.shape)


def trusted_aggregate(grads: Array, ts: Array, eps: float = 1e-12) -> Array:
    """Eq. 13: Σ TS_i·g̃_i / Σ TS_i (g̃ already normalized)."""
    g = grads.reshape(grads.shape[0], -1)
    w = ts / jnp.maximum(jnp.sum(ts), eps)
    return (w @ g).reshape(grads.shape[1:])


def cloud_trust(cloud_grads: Array, global_ref: Array, eps: float = 1e-12) -> Array:
    """β_k (Eq. 6 / Algorithm 1 line 16): cloud-level trust from the cosine
    of each cloud aggregate against the global reference direction,
    ReLU'd and normalized to sum 1."""
    g = cloud_grads.reshape(cloud_grads.shape[0], -1)
    ref = global_ref.reshape(-1)
    cos = (g @ ref) / jnp.maximum(
        jnp.linalg.norm(g, axis=1) * jnp.linalg.norm(ref), eps)
    beta = jax.nn.relu(cos)
    total = jnp.sum(beta)
    k = g.shape[0]
    return jnp.where(total > eps, beta / jnp.maximum(total, eps),
                     jnp.full((k,), 1.0 / k, g.dtype))


# ---------------------------------------------------------------------------
# Pytree helpers (used by the distributed train step)

def tree_dot(a, b) -> Array:
    leaves = jax.tree.map(lambda x, y: jnp.sum(x.astype(jnp.float32) *
                                               y.astype(jnp.float32)), a, b)
    return jax.tree.reduce(jnp.add, leaves)


def tree_norm(t) -> Array:
    return jnp.sqrt(jnp.maximum(tree_dot(t, t), 0.0))


def tree_cos(a, b, eps: float = 1e-12) -> Array:
    return tree_dot(a, b) / jnp.maximum(tree_norm(a) * tree_norm(b), eps)


def tree_scale(t, s):
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * s).astype(x.dtype), t)

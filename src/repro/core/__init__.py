"""Cost-TrustFL core: the paper's contribution as composable JAX modules.

Eq. 1–3  -> repro.core.cost
Eq. 7    -> repro.core.shapley
Eq. 8–9  -> repro.core.reputation
Eq. 10   -> repro.core.selection
Eq. 11–13-> repro.core.trust
Alg. 1   -> repro.core.aggregation (matrix form) /
            repro.train.steps (distributed form) /
            repro.federated.simulation (explicit-client form)
"""
from repro.core.aggregation import AggregationResult, cost_trustfl_aggregate
from repro.core.attacks import (ATTACKS, UPDATE_ATTACKS, alie_attack,
                                apply_update_attack, collusion_attack,
                                flip_labels, gaussian_attack, ipm_attack,
                                min_max_attack, register_update_attack,
                                scaling_attack, sign_flip_attack)
from repro.core.cost import CostModel
from repro.core.fl_types import CloudTopology, RoundMetrics
from repro.core.reputation import ReputationState, ema_update, normalize_scores
from repro.core.robust import (AGGREGATORS, coordinate_median, fedavg, fltrust,
                               krum, trimmed_mean)
from repro.core.selection import select_clients, select_clients_jax
from repro.core.shapley import (cosine_utility, exact_shapley,
                                gradient_contribution, monte_carlo_shapley)
from repro.core.trust import (cloud_trust, normalize_updates, trust_scores,
                              trusted_aggregate, tree_cos, tree_dot, tree_norm,
                              tree_scale)

__all__ = [
    "AggregationResult", "cost_trustfl_aggregate", "ATTACKS",
    "UPDATE_ATTACKS", "register_update_attack", "apply_update_attack",
    "flip_labels", "gaussian_attack", "scaling_attack", "sign_flip_attack",
    "alie_attack", "ipm_attack", "min_max_attack", "collusion_attack",
    "CostModel", "CloudTopology", "RoundMetrics",
    "ReputationState", "ema_update", "normalize_scores", "AGGREGATORS",
    "coordinate_median", "fedavg", "fltrust", "krum", "trimmed_mean",
    "select_clients", "select_clients_jax", "cosine_utility", "exact_shapley",
    "gradient_contribution", "monte_carlo_shapley", "cloud_trust",
    "normalize_updates", "trust_scores", "trusted_aggregate", "tree_cos",
    "tree_dot", "tree_norm", "tree_scale",
]

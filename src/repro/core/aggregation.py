"""Cost-TrustFL hierarchical aggregation (Algorithm 1, lines 3–17) on
explicit (N, D) update matrices — the simulation-scale reference
implementation that the distributed train step mirrors with collectives.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features as feats_mod
from repro.core.reputation import ReputationState, ema_update, normalize_scores
from repro.core.shapley import gradient_contribution
from repro.core.trust import (cloud_trust, normalize_updates, trust_scores,
                              trusted_aggregate)

Array = jax.Array


class AggregationResult(NamedTuple):
    update: Array            # (D,) global update (Eq. 6 inner sum)
    reputation: ReputationState
    trust: Array             # (N,) TS_i
    phi: Array               # (N,) raw contribution scores
    beta: Array              # (K,) cloud trust
    features: Optional[Array] = None      # (N, F) multi-feature matrix
    feat_sep: Optional[Array] = None      # (F,) updated separability EMA
    feat_weights: Optional[Array] = None  # (F,) softmax mixing weights


def cost_trustfl_aggregate(
    updates: Array,                 # (N, D) full client updates
    last_layer: Array,              # (N, L) last-layer slices (Eq. 7 input)
    ref_updates: Array,             # (K, D) per-cloud reference updates
    ref_last_layer: Array,          # (K, L)
    cloud_of: Array,                # (N,) int cloud assignment
    selected: Array,                # (N,) bool participation mask
    rep_state: ReputationState,
    *,
    gamma: float = 0.9,
    eps: float = 1e-12,
    cloud_transform: Optional[Callable[[Array], Array]] = None,
    trust_features: str = "scalar",
    feat_sep: Optional[Array] = None,
) -> AggregationResult:
    """Full Eq. 5–13 pipeline with a two-level (intra-cloud, cross-cloud)
    hierarchy. Non-selected clients are masked out of every sum.

    ``cloud_transform`` models the edge→global wire: it is applied to the
    (K, D) per-cloud aggregates after the intra-cloud phase, BEFORE the
    receiver-side zero-trust fallback and the Eq. 6 combine
    (repro.compress passes the per-link codec round-trip here, so the
    global aggregator only ever sees what actually crossed the cloud
    boundary — and rows it discards in favour of its own reference are
    replaced with the clean, never-transmitted reference)."""
    n, d = updates.shape
    k = ref_updates.shape[0]
    selected = selected.astype(updates.dtype)                      # (N,)
    onehot = jax.nn.one_hot(cloud_of, k, dtype=updates.dtype)      # (N, K)
    ref_ll_per_client = onehot @ ref_last_layer                    # (N, L)

    # --- Eq. 7: contribution vs. the mean of *selected* last-layer grads.
    # The raw ‖g‖ factor in Eq. 7 lets norm-inflating adversaries
    # (scaling, gaussian noise — see repro.scenarios) FARM reputation, so
    # the factor is damped past the median selected norm m: it decays as
    # m²/‖g‖, leaving near-median honest clients untouched. The paper's
    # verbatim score stays in repro.core.shapley.gradient_contribution.
    sel_sum = jnp.sum(selected)
    gbar = (selected @ last_layer) / jnp.maximum(sel_sum, 1.0)
    norms = jnp.linalg.norm(last_layer, axis=1)
    med = jnp.nanmedian(jnp.where(selected > 0, norms, jnp.nan))
    damp = jnp.minimum(1.0, (med / jnp.maximum(norms, eps)) ** 2)
    damp = jnp.where(jnp.isnan(damp), 1.0, damp)
    phi = gradient_contribution(last_layer, gbar) * damp * selected

    # --- multi-feature gate (repro.core.features): phi is scaled by the
    # adaptively-weighted feature vector; the scalar path is untouched.
    features = new_feat_sep = feat_weights = None
    if trust_features == "multi":
        features = feats_mod.client_features(last_layer, ref_ll_per_client,
                                             gbar, med, selected, eps)
        sep_prev = (jnp.zeros((feats_mod.N_FEATURES,), jnp.float32)
                    if feat_sep is None else jnp.asarray(feat_sep))
        sep_round = feats_mod.separability(features, selected, eps)
        new_feat_sep = (feats_mod.FEAT_SEP_RHO * sep_prev +
                        (1.0 - feats_mod.FEAT_SEP_RHO) * sep_round)
        feat_weights = feats_mod.feature_weights(new_feat_sep)
        phi = phi * feats_mod.gate(features, new_feat_sep)
    elif trust_features != "scalar":
        raise ValueError(f"unknown trust_features {trust_features!r}; "
                         "use 'scalar' or 'multi'")

    # --- Eq. 8–9
    r = normalize_scores(phi)
    new_rep = ema_update(rep_state, r, gamma, participated=selected > 0)

    # --- Eq. 11: trust vs. the client's own cloud reference
    ts = jnp.zeros((n,), updates.dtype)
    g = last_layer
    dots = jnp.sum(g * ref_ll_per_client, axis=1)
    cos = dots / jnp.maximum(
        jnp.linalg.norm(g, axis=1) * jnp.linalg.norm(ref_ll_per_client, axis=1),
        eps)
    ts = jax.nn.relu(cos) * new_rep.ema * selected

    # --- Eq. 12: rescale to own-cloud reference norm
    ref_norms = jnp.linalg.norm(ref_updates, axis=1)               # (K,)
    ref_norm_per_client = onehot @ ref_norms
    client_norms = jnp.linalg.norm(updates, axis=1)
    g_tilde = updates * (ref_norm_per_client /
                         jnp.maximum(client_norms, eps))[:, None]

    # --- Eq. 13 per cloud (intra-cloud phase, Eq. 5)
    ts_cloud = onehot.T @ ts                                        # (K,)
    weighted = g_tilde * ts[:, None]
    cloud_aggs = onehot.T @ weighted / jnp.maximum(ts_cloud, eps)[:, None]
    # edge -> global wire (compression) happens on the transmitted
    # aggregates; the zero-trust fallback below is receiver-side and
    # therefore uses the uncompressed local reference
    if cloud_transform is not None:
        cloud_aggs = cloud_transform(cloud_aggs)
    # empty/zero-trust clouds fall back to their reference update
    cloud_aggs = jnp.where((ts_cloud > eps)[:, None], cloud_aggs, ref_updates)

    # --- Eq. 6: cross-cloud phase with β_k from global reference direction
    global_ref = jnp.mean(ref_updates, axis=0)
    beta = cloud_trust(cloud_aggs, global_ref)
    update = beta @ cloud_aggs

    return AggregationResult(update=update, reputation=new_rep, trust=ts,
                             phi=phi, beta=beta, features=features,
                             feat_sep=new_feat_sep,
                             feat_weights=feat_weights)

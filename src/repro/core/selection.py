"""Cost-aware client selection (Eq. 10) with the paper's λ trade-off.

S = argmax_{|S|<=m} Σ_{i∈S} r̂_i / c_i^λ — separable, so the exact optimum
is the top-m of the ratio. λ concretizes the paper's Eq. 4 trade-off knob
inside the selection heuristic: λ=0 ignores cost (pure accuracy), λ=1
recovers Eq. 10 verbatim; the paper's default λ=0.3 makes a cross-cloud
client viable at ~2x the reputation of an intra-cloud one (9x price
ratio ** 0.3). Provided both as numpy (simulation host loop) and as a
jittable masked variant (production step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def select_clients(reputation: np.ndarray, unit_costs: np.ndarray, m: int,
                   per_cloud_min: int = 0,
                   cloud_of: np.ndarray | None = None,
                   cost_lambda: float = 1.0,
                   rng: np.random.Generator | None = None) -> np.ndarray:
    """Boolean (N,) mask of the selected set.

    ``per_cloud_min`` optionally guarantees each cloud a minimum quota
    (keeps edge aggregators alive — used by the hierarchical server).
    ``rng`` adds tiny tie-breaking noise so equal-reputation clients
    rotate across rounds (exploration — unscored clients keep their
    initial reputation otherwise).
    """
    ratio = np.asarray(reputation) / np.asarray(unit_costs) ** cost_lambda
    if rng is not None:
        ratio = ratio * (1.0 + 1e-4 * rng.standard_normal(ratio.shape))
    n = ratio.shape[0]
    m = min(m, n)
    chosen = np.zeros(n, bool)
    if per_cloud_min and cloud_of is not None:
        for k in np.unique(cloud_of):
            idx = np.nonzero(cloud_of == k)[0]
            top = idx[np.argsort(-ratio[idx])[:per_cloud_min]]
            chosen[top] = True
    remaining = m - chosen.sum()
    if remaining > 0:
        order = np.argsort(-np.where(chosen, -np.inf, ratio))
        chosen[order[:remaining]] = True
    return chosen


def select_clients_jax(reputation: Array, unit_costs: Array, m: int,
                       cost_lambda: float = 1.0) -> Array:
    """Jittable Eq. 10: boolean mask of top-m by r̂/c^λ."""
    ratio = reputation / unit_costs ** cost_lambda
    n = ratio.shape[0]
    m = min(m, n)
    _, idx = jax.lax.top_k(ratio, m)
    return jnp.zeros((n,), bool).at[idx].set(True)

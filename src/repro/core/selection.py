"""Cost-aware client selection (Eq. 10) with the paper's λ trade-off.

S = argmax_{|S|<=m} Σ_{i∈S} r̂_i / c_i^λ — separable, so the exact optimum
is the top-m of the ratio. λ concretizes the paper's Eq. 4 trade-off knob
inside the selection heuristic: λ=0 ignores cost (pure accuracy), λ=1
recovers Eq. 10 verbatim; the paper's default λ=0.3 makes a cross-cloud
client viable at ~2x the reputation of an intra-cloud one (9x price
ratio ** 0.3). Provided both as numpy (simulation host loop) and as a
jittable masked variant (production step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def select_clients(reputation: np.ndarray, unit_costs: np.ndarray, m: int,
                   per_cloud_min: int = 0,
                   cloud_of: np.ndarray | None = None,
                   cost_lambda: float = 1.0,
                   rng: np.random.Generator | None = None) -> np.ndarray:
    """Boolean (N,) mask of the selected set.

    ``per_cloud_min`` optionally guarantees each cloud a minimum quota
    (keeps edge aggregators alive — used by the hierarchical server).
    ``rng`` adds tiny tie-breaking noise so equal-reputation clients
    rotate across rounds (exploration — unscored clients keep their
    initial reputation otherwise).
    """
    ratio = np.asarray(reputation) / np.asarray(unit_costs) ** cost_lambda
    if rng is not None:
        ratio = ratio * (1.0 + 1e-4 * rng.standard_normal(ratio.shape))
    n = ratio.shape[0]
    m = min(m, n)
    chosen = np.zeros(n, bool)
    if per_cloud_min and cloud_of is not None:
        for k in np.unique(cloud_of):
            idx = np.nonzero(cloud_of == k)[0]
            top = idx[np.argsort(-ratio[idx])[:per_cloud_min]]
            chosen[top] = True
    remaining = m - chosen.sum()
    if remaining > 0:
        order = np.argsort(-np.where(chosen, -np.inf, ratio))
        chosen[order[:remaining]] = True
    return chosen


def exploration_quota(cost_lambda: float) -> int:
    """Per-cloud exploration quota for Cost-TrustFL selection. The quota
    is itself part of the λ trade-off: at high λ the budget concentrates
    on cheap clouds (inactive clouds then skip their cross-cloud upload —
    this is where Fig. 7's cost knee comes from). Single source for the
    host loop and the device engine, so both resolve the same static
    selected-set size."""
    return 2 if cost_lambda < 0.75 else 0


def selected_count(n: int, m: int, per_cloud_min: int = 0,
                   cloud_of: np.ndarray | None = None) -> int:
    """Static size of the selected set: quota picks are disjoint per
    cloud, then the pool is filled to ``m`` — so the count is
    max(min(m, n), Σ_k min(per_cloud_min, n_k)), a pure function of the
    (static) topology. The jittable engine relies on this to keep the
    per-round training batch a fixed shape under jit/scan."""
    m = min(m, n)
    if not per_cloud_min or cloud_of is None:
        return m
    cloud_of = np.asarray(cloud_of)
    quota = sum(min(per_cloud_min, int((cloud_of == k).sum()))
                for k in np.unique(cloud_of))
    return max(m, quota)


def select_clients_jax(reputation: Array, unit_costs: Array, m: int,
                       cost_lambda: float = 1.0, *,
                       per_cloud_min: int = 0,
                       cloud_of: np.ndarray | None = None,
                       key: Array | None = None) -> Array:
    """Jittable Eq. 10 matching the numpy path's semantics: boolean mask
    of the top-m by r̂/c^λ, with the optional per-cloud quota and
    multiplicative tie-break noise.

    ``cloud_of`` must be a *static* (numpy) assignment — the per-cloud
    quotas and the fill count are resolved at trace time so the mask has
    a fixed population count under jit/scan/vmap. ``key`` draws the
    1e-4-relative exploration noise (the jax analogue of the numpy
    path's ``rng``)."""
    ratio = reputation / unit_costs ** cost_lambda
    if key is not None:
        ratio = ratio * (1.0 + 1e-4 * jax.random.normal(key, ratio.shape,
                                                        ratio.dtype))
    n = ratio.shape[0]
    m = min(m, n)
    if not per_cloud_min or cloud_of is None:
        _, idx = jax.lax.top_k(ratio, m)
        return jnp.zeros((n,), bool).at[idx].set(True)
    cloud_of = np.asarray(cloud_of)
    chosen = jnp.zeros((n,), bool)
    quota_total = 0
    for k in np.unique(cloud_of):
        in_k = cloud_of == k
        q = min(per_cloud_min, int(in_k.sum()))
        quota_total += q
        masked = jnp.where(jnp.asarray(in_k), ratio, -jnp.inf)
        _, top = jax.lax.top_k(masked, q)
        chosen = chosen.at[top].set(True)
    remaining = m - quota_total
    if remaining > 0:
        masked = jnp.where(chosen, -jnp.inf, ratio)
        _, top = jax.lax.top_k(masked, remaining)
        chosen = chosen.at[top].set(True)
    return chosen

"""Poisoning attacks: the paper's threat model (§III-B, §V-A) plus the
adaptive adversaries used by the scenario engine (`repro.scenarios`).

Update-level attacks are jittable transforms of the malicious rows of an
(N, D) update matrix, dispatched by name through ``UPDATE_ATTACKS`` so
new adversaries plug into ``FLServer`` without touching the round loop.

Static (paper Table I):
  * ``label_flip``  — data-level (see :func:`flip_labels`); identity here
  * ``gaussian``    — additive N(0, σ²) noise
  * ``sign_flip``   — g ← −scale·g
  * ``scaling``     — g ← scale·g (model replacement)

Adaptive (out-of-paper extensions, after Baruch et al. "A Little Is
Enough", Xie et al. IPM, Shejwalkar & Houmansadr min-max):
  * ``alie``       — malicious rows hide at mean − z·std of honest rows
  * ``ipm``        — inner-product manipulation: rows at −ε·mean(honest)
  * ``min_max``    — largest perturbation that stays within the honest
                     pairwise-distance envelope (bisection, jittable)
  * ``collusion``  — colluders agree on one update (−scale · their mean)
    so mutual similarity mimics consensus
  * ``alie_norm``  — reputation-aware ALIE: the evasion point is rescaled
    to the honest MEDIAN norm, so the Eq. 7 median damp (which decays
    with ‖g‖ past the median) reads the attacker as perfectly typical
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def flip_labels(labels: Array, n_classes: int, mask: Array, key: Array) -> Array:
    """Label flipping: randomly permute labels of poisoned examples.
    ``mask`` is a boolean per-example poison mask."""
    offset = jax.random.randint(key, labels.shape, 1, n_classes)
    flipped = (labels + offset) % n_classes
    return jnp.where(mask, flipped, labels)


def _row_mask(malicious: Array, ndim: int) -> Array:
    return malicious.reshape((-1,) + (1,) * (ndim - 1))


def _honest_moments(updates: Array, malicious: Array,
                    valid: Optional[Array] = None,
                    eps: float = 1e-12) -> tuple[Array, Array]:
    """Per-coordinate (mean, std) over the honest rows of (N, D).
    ``valid`` (bool (N,), optional) excludes rows that never delivered
    (dropout under the jittable engine) from the honest statistics —
    adaptive adversaries can only condition on traffic that exists."""
    honest = ~malicious if valid is None else (~malicious) & valid
    w = honest.astype(updates.dtype)[:, None]
    n = jnp.maximum(jnp.sum(w), 1.0)
    mean = jnp.sum(updates * w, axis=0) / n
    var = jnp.sum(((updates - mean) ** 2) * w, axis=0) / n
    return mean, jnp.sqrt(jnp.maximum(var, eps * eps))


def gaussian_attack(updates: Array, malicious: Array, key: Array,
                    sigma: float = 1.0) -> Array:
    """g_i += N(0, σ²) for malicious rows."""
    noise = sigma * jax.random.normal(key, updates.shape, updates.dtype)
    return jnp.where(_row_mask(malicious, updates.ndim),
                     updates + noise, updates)


def sign_flip_attack(updates: Array, malicious: Array, scale: float = 1.0) -> Array:
    """g_i ← −scale · g_i for malicious rows."""
    return jnp.where(_row_mask(malicious, updates.ndim),
                     -scale * updates, updates)


def scaling_attack(updates: Array, malicious: Array, scale: float = 10.0) -> Array:
    """g_i ← scale · g_i (model-replacement style amplification)."""
    return jnp.where(_row_mask(malicious, updates.ndim),
                     scale * updates, updates)


def alie_attack(updates: Array, malicious: Array, z: float = 1.0,
                valid: Optional[Array] = None) -> Array:
    """A-little-is-enough: every malicious row moves to mean − z·std of
    the honest rows — inside the per-coordinate envelope that outlier
    filters (trimmed mean, Krum distances) treat as benign."""
    mean, std = _honest_moments(updates, malicious, valid)
    return jnp.where(malicious[:, None], mean - z * std, updates)


def alie_norm_attack(updates: Array, malicious: Array, z: float = 1.0,
                     valid: Optional[Array] = None,
                     eps: float = 1e-12) -> Array:
    """Reputation-aware ALIE: the mean − z·std evasion point is rescaled
    to the honest rows' MEDIAN norm. The scalar Eq. 7 defense damps
    contributions by (med/‖g‖)² — an attacker sitting exactly at the
    median norm takes no damping at all, so only richer per-update
    signals (sign agreement, reference cosine — see
    ``repro.core.features``) can tell it apart."""
    mean, std = _honest_moments(updates, malicious, valid, eps)
    point = mean - z * std
    honest = ~malicious if valid is None else (~malicious) & valid
    norms = jnp.linalg.norm(updates, axis=1)
    med = jnp.nanmedian(jnp.where(honest, norms, jnp.nan))
    med = jnp.where(jnp.isnan(med) | ~(med > 0), 1.0, med)
    point = point * (med / jnp.maximum(jnp.linalg.norm(point), eps))
    return jnp.where(malicious[:, None], point, updates)


def ipm_attack(updates: Array, malicious: Array, scale: float = 2.0,
               valid: Optional[Array] = None) -> Array:
    """Inner-product manipulation: malicious rows submit −ε·mean(honest)
    so the aggregate's inner product with the true descent direction
    turns negative once ε·frac_malicious is large enough."""
    mean, _ = _honest_moments(updates, malicious, valid)
    return jnp.where(malicious[:, None], -scale * mean, updates)


def min_max_attack(updates: Array, malicious: Array, *, iters: int = 20,
                   valid: Optional[Array] = None,
                   eps: float = 1e-12) -> Array:
    """Min-max distance evasion (Shejwalkar & Houmansadr): malicious rows
    sit at mean(honest) + γ·p with p = −mean/‖mean‖ and γ the largest
    value (bisection) keeping the row's distance to every honest row
    within the maximum honest pairwise distance."""
    honest = ~malicious if valid is None else (~malicious) & valid
    w = honest.astype(updates.dtype)
    mean, _ = _honest_moments(updates, malicious, valid)
    p = -mean / jnp.maximum(jnp.linalg.norm(mean), eps)

    # pairwise honest distances via the Gram matrix — O(N^2) memory,
    # never materializes an (N, N, D) tensor
    sq = jnp.sum(updates * updates, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (updates @ updates.T)
    d_max = jnp.sqrt(jnp.maximum(jnp.max(d2 * w[:, None] * w[None, :]), 0.0))

    mean_sq = jnp.sum(mean * mean)
    dot_up = updates @ p
    dot_um = updates @ mean

    def worst_dist(gamma):
        # ||(mean + γp) - u_j||² expanded; masked to honest rows
        cand_sq = mean_sq + 2.0 * gamma * (mean @ p) + gamma * gamma
        d = cand_sq + sq - 2.0 * (dot_um + gamma * dot_up)
        return jnp.sqrt(jnp.maximum(jnp.max(jnp.where(honest, d, -jnp.inf)),
                                    0.0))

    def body(carry, _):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        ok = worst_dist(mid) <= d_max
        return (jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)), None

    zero = jnp.asarray(0.0, updates.dtype)
    (gamma, _), _ = jax.lax.scan(body, (zero, 2.0 * d_max + eps),
                                 None, length=iters)
    return jnp.where(malicious[:, None], mean + gamma * p, updates)


def collusion_attack(updates: Array, malicious: Array,
                     scale: float = 1.0,
                     valid: Optional[Array] = None) -> Array:
    """Collusion: every malicious row submits the same −scale·mean of the
    colluders' true updates — pairwise-identical rows defeat similarity /
    distance heuristics that assume attackers are outliers."""
    colluders = malicious if valid is None else malicious & valid
    w = colluders.astype(updates.dtype)
    n_m = jnp.maximum(jnp.sum(w), 1.0)
    mal_mean = (w @ updates) / n_m
    return jnp.where(malicious[:, None], -scale * mal_mean, updates)


# -- registry -----------------------------------------------------------------
# Normalized signature: fn(updates, malicious, key, *, sigma, scale, z,
# valid). ``None`` marks names that are handled at the data level (or
# no-ops) so the server's dispatch stays a single lookup. Each adapter
# forwards only the knobs its attack reads; ``valid`` (delivered mask)
# only matters to the honest-statistics adversaries.
AttackFn = Callable[..., Array]

UPDATE_ATTACKS: Dict[str, Optional[AttackFn]] = {}


def register_update_attack(name: str, fn: Optional[AttackFn]) -> None:
    UPDATE_ATTACKS[name] = fn


register_update_attack("none", None)
register_update_attack("label_flip", None)   # data level, see flip_labels
register_update_attack(
    "gaussian", lambda u, m, k, *, sigma, scale, z, valid=None:
        gaussian_attack(u, m, k, sigma))
register_update_attack(
    "sign_flip", lambda u, m, k, *, sigma, scale, z, valid=None:
        sign_flip_attack(u, m, scale))
register_update_attack(
    "scaling", lambda u, m, k, *, sigma, scale, z, valid=None:
        scaling_attack(u, m, scale))
register_update_attack(
    "alie", lambda u, m, k, *, sigma, scale, z, valid=None:
        alie_attack(u, m, z, valid))
register_update_attack(
    "alie_norm", lambda u, m, k, *, sigma, scale, z, valid=None:
        alie_norm_attack(u, m, z, valid))
register_update_attack(
    "ipm", lambda u, m, k, *, sigma, scale, z, valid=None:
        ipm_attack(u, m, scale, valid))
register_update_attack(
    "min_max", lambda u, m, k, *, sigma, scale, z, valid=None:
        min_max_attack(u, m, valid=valid))
register_update_attack(
    "collusion", lambda u, m, k, *, sigma, scale, z, valid=None:
        collusion_attack(u, m, scale, valid))


def apply_update_attack(name: str, updates: Array, malicious: Array,
                        key: Array, *, sigma: float = 1.0,
                        scale: float = 10.0, z: float = 1.0,
                        valid: Optional[Array] = None) -> Array:
    if name not in UPDATE_ATTACKS:
        raise ValueError(f"unknown attack {name!r}; known: "
                         f"{sorted(UPDATE_ATTACKS)}")
    fn = UPDATE_ATTACKS[name]
    if fn is None:
        return updates
    if valid is None:
        # omit the kwarg so attacks registered with the pre-`valid`
        # adapter signature keep working (full delivery is the default)
        return fn(updates, malicious, key, sigma=sigma, scale=scale, z=z)
    return fn(updates, malicious, key, sigma=sigma, scale=scale, z=z,
              valid=valid)


ATTACKS = tuple(UPDATE_ATTACKS)

"""Poisoning attacks from the paper's threat model (§III-B, §V-A):
label flipping (data-level), Gaussian noise, sign flipping, and scaling
(update-level). Update-level attacks are jittable transforms of the
malicious rows of an (N, D) update matrix.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

Array = jax.Array


def flip_labels(labels: Array, n_classes: int, mask: Array, key: Array) -> Array:
    """Label flipping: randomly permute labels of poisoned examples.
    ``mask`` is a boolean per-example poison mask."""
    offset = jax.random.randint(key, labels.shape, 1, n_classes)
    flipped = (labels + offset) % n_classes
    return jnp.where(mask, flipped, labels)


def gaussian_attack(updates: Array, malicious: Array, key: Array,
                    sigma: float = 1.0) -> Array:
    """g_i += N(0, σ²) for malicious rows."""
    noise = sigma * jax.random.normal(key, updates.shape, updates.dtype)
    m = malicious.reshape((-1,) + (1,) * (updates.ndim - 1))
    return jnp.where(m, updates + noise, updates)


def sign_flip_attack(updates: Array, malicious: Array, scale: float = 1.0) -> Array:
    """g_i ← −scale · g_i for malicious rows."""
    m = malicious.reshape((-1,) + (1,) * (updates.ndim - 1))
    return jnp.where(m, -scale * updates, updates)


def scaling_attack(updates: Array, malicious: Array, scale: float = 10.0) -> Array:
    """g_i ← scale · g_i (model-replacement style amplification)."""
    m = malicious.reshape((-1,) + (1,) * (updates.ndim - 1))
    return jnp.where(m, scale * updates, updates)


def apply_update_attack(name: str, updates: Array, malicious: Array,
                        key: Array, *, sigma: float = 1.0,
                        scale: float = 10.0) -> Array:
    if name in ("none", "label_flip"):   # label_flip happens at data level
        return updates
    if name == "gaussian":
        return gaussian_attack(updates, malicious, key, sigma)
    if name == "sign_flip":
        return sign_flip_attack(updates, malicious, scale=1.0)
    if name == "scaling":
        return scaling_attack(updates, malicious, scale)
    raise ValueError(f"unknown attack {name!r}")


ATTACKS = ("none", "label_flip", "gaussian", "sign_flip", "scaling")

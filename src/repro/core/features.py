"""Multi-feature trust scoring (OptiGradTrust / FLARE style).

Eq. 7's scalar contribution score is a single norm-damped cosine — a
one-dimensional view an adaptive adversary can sit exactly on top of
(ALIE picks mean − z·std; a norm-matched variant also defeats the
median damp). This module widens the per-client signal to a small
feature vector computed in ONE pass over the delivered last-layer
matrix, then learns how much each feature separates honest from
malicious behaviour *online* via an EMA of per-feature separability
(softmax-normalized, as in FLARE's adaptive dimensions).

Features (all in [0, 1], all per-row so the sharded engine can compute
them locally from globally-reduced ``gbar``/``med``):

  f0 norm_profile    1 / (1 + |log(‖g_i‖ / med)|) — peaks at the
                     selected-median norm, decays for both inflated and
                     vanishing updates.
  f1 ref_cosine      ReLU(cos(g_i, ref_k(i))) — direction agreement
                     with the client's own-cloud reference update.
  f2 sign_agreement  fraction of coordinates where sign(g_id) matches
                     sign(ḡ_d) (zero coordinates count as disagreement,
                     which makes zero-padding safe).
  f3 loss_delta      saturating first-order loss-decrease proxy
                     x / (1 + x) with
                     x = ReLU(cos(g_i, ref)) · min(‖g_i‖/med, med/‖g_i‖)
                     — the loss decrease a reference-gradient step
                     attributes to client i, with the norm factor made
                     SYMMETRIC around the selected median. The symmetry
                     matters: on the raw inner product a scaling
                     adversary inflates x linearly and reads as the
                     round's best contributor, and a one-sided clip
                     min(‖g‖, med) still hands every norm-inflator the
                     maximal factor; min(r, 1/r) decays for inflated
                     AND vanishing updates alike.

The adaptive weighting needs a trustworthy supervision signal. The
reputation EMA is NOT one: a sleeper adversary farms reputation while
honest, so rep-supervised weights learn to favour exactly the features
the attacker then scores well on (and Eq. 7's mean-anchored cosine is
equally capturable — ALIE sits on the mean). The one signal clients
cannot poison is the server's own reference gradient, the paper's
Eq. 11 trust anchor — so per-feature separability is the POSITIVE
PART of the weighted Pearson correlation between the feature and the
ref-cosine anchor (``ANCHOR_FEATURE`` = f1) over delivered rows,
EMA-tracked across rounds (``FEAT_SEP_RHO``) and softmax-normalized
(temperature ``WEIGHT_TEMP``) into mixing weights. The anchor's own
separability is 1 by definition; population-anchored features (norm
profile, sign agreement) only earn weight in rounds where they
corroborate the reference anchor, and the positive part zeroes any
feature an adversary has captured (which shows up as anti-correlation
with the anchor).

The multi-feature score gates Eq. 7 with confidence proportional to
the best separability seen so far:

    phi_multi = phi_scalar · (1 − β + β · (F @ weights)),
    β = max_f feat_sep_f ∈ [0, 1]

so with no evidence (round 0, or features that never track reputation)
the gate is exactly 1 — multi degrades to the scalar path instead of
injecting noise — and it only bites where some feature demonstrably
ranks the way reputation does (Eq. 8 normalizes away absolute scale).

The fused Pallas pass lives in ``repro.kernels.trust_features``;
:func:`client_features` is its jnp oracle and the implementation the
engines trace.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

N_FEATURES = 4
FEATURE_NAMES = ("norm_profile", "ref_cosine", "sign_agreement",
                 "loss_delta")
FEAT_SEP_RHO = 0.5      # EMA factor for per-feature separability
WEIGHT_TEMP = 0.2       # softmax temperature over separability ∈ [0,1]
ANCHOR_FEATURE = 1      # ref_cosine: the unpoisonable supervision anchor
CONSENSUS_FEATURE = 0   # norm_profile: the direction-independent witness
BETA_MAX = 0.3          # cap on the gate's multiplicative range


def client_features(last_layer: Array,    # (m, L) delivered last-layer rows
                    ref_rows: Array,      # (m, L) own-cloud reference rows
                    gbar: Array,          # (L,) selected-mean last layer
                    med: Array,           # scalar selected-median norm
                    w: Array,             # (m,) delivery weights in {0,1}
                    eps: float = 1e-12) -> Array:
    """Per-client feature matrix (m, N_FEATURES); rows with w == 0 are
    all-zero. ``med`` may be NaN/non-positive (empty selection) — it is
    sanitized to 1 so the features stay finite."""
    g = last_layer.astype(jnp.float32)
    r = ref_rows.astype(jnp.float32)
    wv = w.astype(jnp.float32)
    med = jnp.asarray(med, jnp.float32)
    med = jnp.where(jnp.isnan(med) | ~(med > 0), 1.0, med)

    norms = jnp.linalg.norm(g, axis=1)                         # (m,)
    ref_norms = jnp.linalg.norm(r, axis=1)
    dots = jnp.sum(g * r, axis=1)

    f0 = 1.0 / (1.0 + jnp.abs(jnp.log(jnp.maximum(norms, eps) / med)))
    f1 = jax.nn.relu(dots / jnp.maximum(norms * ref_norms, eps))
    f2 = jnp.mean((g * gbar.astype(jnp.float32)[None, :] > 0)
                  .astype(jnp.float32), axis=1)
    ratio = jnp.maximum(norms, eps) / med
    profile = jnp.minimum(ratio, 1.0 / ratio)
    x = f1 * profile
    f3 = x / (1.0 + x)

    feats = jnp.stack([f0, f1, f2, f3], axis=1)                # (m, F)
    return feats * wv[:, None]


def separability_sums(feats: Array,       # (m, F)
                      w: Array            # (m,) delivery weights
                      ) -> Array:
    """The six weighted sums a Pearson correlation against the anchor
    column needs, stacked as (6, F) so the sharded engine reduces them
    in ONE psum: rows are [Σw, Σw·f, Σw·a, Σw·f², Σw·a², Σw·f·a]
    (a = the ``ANCHOR_FEATURE`` column, broadcast over F)."""
    wv = w.astype(jnp.float32)[:, None]                        # (m, 1)
    f = feats.astype(jnp.float32)
    r = f[:, ANCHOR_FEATURE][:, None]                          # (m, 1)
    ones = jnp.ones_like(f)
    return jnp.stack([
        jnp.sum(wv * ones, axis=0),
        jnp.sum(wv * f, axis=0),
        jnp.sum(wv * r * ones, axis=0),
        jnp.sum(wv * f * f, axis=0),
        jnp.sum(wv * r * r * ones, axis=0),
        jnp.sum(wv * f * r, axis=0),
    ], axis=0)                                                 # (6, F)


def separability_from_sums(sums: Array, eps: float = 1e-12) -> Array:
    """ReLU(weighted Pearson corr(feature, anchor)) per feature, (F,).
    Anti-correlated features (a captured signal — see module docstring)
    and degenerate rounds (no delivered rows, or zero variance in
    either marginal) yield 0, i.e. 'no evidence this round'. The
    anchor's own entry is its self-correlation, 1, whenever it varies
    at all."""
    sw = jnp.maximum(sums[0], eps)
    mean_f = sums[1] / sw
    mean_r = sums[2] / sw
    var_f = jnp.maximum(sums[3] / sw - mean_f ** 2, 0.0)
    var_r = jnp.maximum(sums[4] / sw - mean_r ** 2, 0.0)
    cov = sums[5] / sw - mean_f * mean_r
    corr = cov / jnp.sqrt(jnp.maximum(var_f * var_r, eps * eps))
    corr = jnp.where((var_f > eps) & (var_r > eps), corr, 0.0)
    return jnp.clip(corr, 0.0, 1.0)


def separability(feats: Array, w: Array, eps: float = 1e-12) -> Array:
    """Single-host convenience: (F,) separability of this round."""
    return separability_from_sums(separability_sums(feats, w), eps)


def feature_weights(feat_sep: Array) -> Array:
    """Softmax mixing weights from the EMA-tracked separability. With
    no evidence yet (all-zero EMA) this is exactly uniform; the
    temperature sharpens toward the features that track reputation."""
    return jax.nn.softmax(feat_sep.astype(jnp.float32) / WEIGHT_TEMP)


def gate_strength(feat_sep: Array) -> Array:
    """Confidence β ∈ [0, BETA_MAX] of the multiplicative gate.

    Confidence requires corroboration from an INDEPENDENT modality:
    the separability the norm profile — the one feature that measures
    norm typicality, not direction — has accumulated against the
    direction anchor. Every other feature is itself direction-based
    (the anchor trivially self-correlates at 1, the loss-delta proxy
    shares its ReLU cosine factor, sign agreement is coordinate-wise
    direction typicality), so their correlation with the anchor is not
    evidence that the gate sees anything Eq. 7 does not — without the
    two-modality requirement the gate fires confidently on attacks it
    cannot see (pure scaling preserves direction exactly) and only
    injects heterogeneity noise into near-tied scores. Capped at
    BETA_MAX so the gate can only reorder clients whose scalar scores
    are within a ~1/(1−BETA_MAX) ratio — a corrective nudge on top of
    Eq. 7, never a replacement for it. Zero evidence → zero gate →
    phi_multi ≡ phi_scalar."""
    sep0 = feat_sep.astype(jnp.float32)[CONSENSUS_FEATURE]
    return BETA_MAX * jnp.clip(sep0, 0.0, 1.0)


def gate(feats: Array, feat_sep: Array) -> Array:
    """The (m,) multiplicative trust gate: 1 − β + β·(F @ weights)."""
    beta = gate_strength(feat_sep)
    return 1.0 - beta + beta * (feats @ feature_weights(feat_sep))

"""Shared pytree/dataclass types for the Cost-TrustFL core."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class CloudTopology:
    """Static client→cloud assignment.

    ``cloud_of[i]`` is the cloud index of client ``i``;
    ``aggregator_cloud`` is where the global aggregator lives (clients in
    that cloud pay ``c_intra`` to reach it, Eq. 2).
    """
    cloud_of: np.ndarray          # (N,) int
    n_clouds: int
    aggregator_cloud: int = 0

    @property
    def n_clients(self) -> int:
        return int(self.cloud_of.shape[0])

    def clients_in(self, k: int) -> np.ndarray:
        return np.nonzero(self.cloud_of == k)[0]

    @staticmethod
    def even(n_clouds: int, clients_per_cloud: int, aggregator_cloud: int = 0
             ) -> "CloudTopology":
        cloud_of = np.repeat(np.arange(n_clouds), clients_per_cloud)
        return CloudTopology(cloud_of=cloud_of, n_clouds=n_clouds,
                             aggregator_cloud=aggregator_cloud)


@dataclass
class RoundMetrics:
    """Per-round bookkeeping returned by aggregators/servers."""
    round: int = 0
    loss: float = 0.0
    accuracy: float = 0.0
    cost: float = 0.0                 # $ this round (Eq. 1)
    cum_cost: float = 0.0             # Σ over rounds
    selected: Optional[np.ndarray] = None
    reputation: Optional[np.ndarray] = None
    trust: Optional[np.ndarray] = None
    extra: Optional[Dict[str, Any]] = None

"""Reputation normalization + EMA smoothing (Eq. 8–9)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class ReputationState(NamedTuple):
    """Persistent per-client reputation r̂ (Eq. 9). ``ema`` has shape (N,)."""
    ema: Array

    @staticmethod
    def init(n_clients: int, dtype=jnp.float32) -> "ReputationState":
        # Algorithm 1 line 1: r̂_i^(0) = 1/N
        return ReputationState(ema=jnp.full((n_clients,), 1.0 / n_clients, dtype))


def normalize_scores(phi: Array, eps: float = 1e-12) -> Array:
    """Eq. 8: r_i = φ_i / Σ_j φ_j (uniform if all-zero)."""
    total = jnp.sum(phi)
    n = phi.shape[0]
    uniform = jnp.full_like(phi, 1.0 / n)
    return jnp.where(total > eps, phi / jnp.maximum(total, eps), uniform)


def ema_update(state: ReputationState, r: Array, gamma: float,
               participated: Array | None = None) -> ReputationState:
    """Eq. 9: r̂^(t) = γ·r̂^(t-1) + (1-γ)·r^(t).

    ``participated`` (bool (N,)) restricts the update to clients that were
    selected this round — non-participants keep their previous reputation
    (the paper updates only scored clients; unscored φ would be 0).
    """
    new = gamma * state.ema + (1.0 - gamma) * r
    if participated is not None:
        new = jnp.where(participated, new, state.ema)
    return ReputationState(ema=new)

"""Communication cost model (paper §III-C, Eq. 1–3) with byte-exact
per-link payloads.

Costs are expressed in $ per round for a model of ``d`` parameters at
``bytes_per_param`` (default fp32 upload, matching the paper's setup).
Prices are $/GB; AWS-style egress defaults are in FLConfig. When
``repro.compress`` is active, the per-link payload overrides
(``client_payload`` bytes per client uplink, ``edge_payload`` bytes per
edge→global uplink) replace the fp32 default, so the $ figures track the
actual wire traffic of compressed runs.

All per-cloud reductions are numpy segment ops (``np.bincount``) — no
Python loops over clouds, so the model stays O(N + K) at any topology
size.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.fl_types import CloudTopology

_GB = 1024.0 ** 3

PayloadLike = Union[None, int, float, np.ndarray]


def _as_payload(payload: PayloadLike, n: int, default: float) -> np.ndarray:
    """Broadcast a scalar/array payload spec to a float64 (n,) vector."""
    if payload is None:
        return np.full(n, default, np.float64)
    return np.broadcast_to(np.asarray(payload, np.float64), (n,)).copy()


@dataclass(frozen=True)
class CostModel:
    c_intra: float = 0.01     # $/GB within a cloud
    c_cross: float = 0.09     # $/GB cross-cloud egress
    bytes_per_param: int = 4

    def client_unit_costs(self, topo: CloudTopology) -> np.ndarray:
        """c_i (Eq. 2): per-GB price for client i to reach the global
        aggregator's cloud (the FLAT upload path)."""
        same = topo.cloud_of == topo.aggregator_cloud
        return np.where(same, self.c_intra, self.c_cross)

    def _edge_prices(self, topo: CloudTopology) -> np.ndarray:
        """(K,) $/GB of each cloud's edge→global uplink."""
        prices = np.full(topo.n_clouds, self.c_cross, np.float64)
        prices[topo.aggregator_cloud] = self.c_intra
        return prices

    def hierarchical_unit_costs(self, topo: CloudTopology) -> np.ndarray:
        """Marginal per-client cost under HIERARCHICAL aggregation: every
        client uploads intra-cloud to its edge aggregator; the single
        cross-cloud edge->global upload is amortized over the cloud's
        clients. This is the c_i that Eq. 10 sees inside Cost-TrustFL
        itself — near-uniform, so selection stays reputation-driven and
        clouds are not starved (the cost saving comes from the hierarchy,
        not from abandoning remote clouds)."""
        sizes = np.bincount(topo.cloud_of, minlength=topo.n_clouds)
        amortized = self._edge_prices(topo) / np.maximum(sizes, 1)
        return self.c_intra + amortized[topo.cloud_of]

    def round_bytes(self, topo: CloudTopology, selected: np.ndarray,
                    d_params: int, *, hierarchical: bool = True,
                    client_payload: PayloadLike = None,
                    edge_payload: PayloadLike = None
                    ) -> Tuple[float, float]:
        """Exact (intra_bytes, cross_bytes) on the wire for one round.

        ``client_payload``: bytes of one client uplink — scalar or (N,);
        defaults to ``bytes_per_param * d_params`` (fp32).
        ``edge_payload``: bytes of one edge→global uplink — scalar or
        (K,); hierarchical path only. The aggregator cloud's edge uplink
        is co-located, so its bytes count as *intra* traffic.
        """
        full = float(self.bytes_per_param) * d_params
        sel = np.asarray(selected, bool)
        cp = _as_payload(client_payload, topo.n_clients, full)
        if not hierarchical:
            same = topo.cloud_of == topo.aggregator_cloud
            return (float(cp[sel & same].sum()),
                    float(cp[sel & ~same].sum()))
        intra = float(cp[sel].sum())                 # client -> edge
        active = np.bincount(topo.cloud_of[sel],
                             minlength=topo.n_clouds) > 0
        ep = _as_payload(edge_payload, topo.n_clouds, full) * active
        cross = float(ep.sum() - ep[topo.aggregator_cloud])
        intra += float(ep[topo.aggregator_cloud])
        return intra, cross

    def bytes_per_round(self, topo: CloudTopology, selected: np.ndarray,
                        d_params: int, *, hierarchical: bool = True,
                        client_payload: PayloadLike = None,
                        edge_payload: PayloadLike = None
                        ) -> Dict[str, float]:
        """Intra/cross breakdown of one round's traffic, in bytes."""
        intra, cross = self.round_bytes(
            topo, selected, d_params, hierarchical=hierarchical,
            client_payload=client_payload, edge_payload=edge_payload)
        return {"intra": intra, "cross": cross, "total": intra + cross}

    def round_cost(self, topo: CloudTopology, selected: np.ndarray,
                   d_params: int, hierarchical: bool = True, *,
                   client_payload: PayloadLike = None,
                   edge_payload: PayloadLike = None) -> float:
        """$ cost of one round (Eq. 1 flat, or the hierarchical variant).

        ``selected``: boolean (N,) participation mask.
        Hierarchical (Eq. 3 structure): every selected client uploads
        intra-cloud to its edge aggregator; each cloud with >=1 selected
        client sends ONE cross-cloud aggregate (clouds co-located with the
        global aggregator pay intra).
        """
        intra_b, cross_b = self.round_bytes(
            topo, selected, d_params, hierarchical=hierarchical,
            client_payload=client_payload, edge_payload=edge_payload)
        return float((intra_b * self.c_intra + cross_b * self.c_cross) / _GB)

    def full_participation_cost(self, topo: CloudTopology, d_params: int) -> float:
        """Eq. 3 upper bound: Σ_k n_k·d·C_intra + K·d·C_cross."""
        gb = d_params * self.bytes_per_param / _GB
        return float(gb * self.c_intra * topo.n_clients +
                     gb * self.c_cross * topo.n_clouds)

    def collective_egress_dollars(self, cross_pod_bytes: int) -> float:
        """Price measured cross-pod collective traffic (from the compiled
        HLO, see repro.roofline) at the egress rate — the TPU-mapping of
        the paper's cross-cloud fee."""
        return cross_pod_bytes / _GB * self.c_cross


# ---------------------------------------------------------------------------
# Jittable mirrors (repro.federated.engine): the same Eq. 1/3 accounting
# as jnp ops so the scanned round engine can carry running bytes/cost in
# device state. float32 byte counts are exact up to 2^24 bytes per link
# class per round (all test/benchmark configs); SimResult totals are
# still reduced on the host in float64 from the per-round delivered
# masks, so the $ figures stay byte-exact at any scale.

def round_bytes_jax(delivered, cloud_of, aggregator_cloud: int,
                    client_payload, edge_payload, *,
                    hierarchical: bool = True):
    """(intra_bytes, cross_bytes) of one round as jnp scalars.

    ``delivered``: (N,) bool/float participation. ``cloud_of`` may be a
    traced or static (N,) int array; ``aggregator_cloud`` and the
    payload vectors ((N,) and (K,)) are static per config.
    """
    w = delivered.astype(jnp.float32)
    cp = jnp.asarray(client_payload, jnp.float32)
    cloud_of = jnp.asarray(cloud_of)
    same = (cloud_of == aggregator_cloud).astype(jnp.float32)
    if not hierarchical:
        intra = jnp.sum(cp * w * same)
        cross = jnp.sum(cp * w * (1.0 - same))
        return intra, cross
    ep = jnp.asarray(edge_payload, jnp.float32)
    k = ep.shape[0]
    per_cloud = jnp.zeros((k,), jnp.float32).at[cloud_of].add(w)
    active = (per_cloud > 0).astype(jnp.float32)
    ep = ep * active
    intra = jnp.sum(cp * w) + ep[aggregator_cloud]
    cross = jnp.sum(ep) - ep[aggregator_cloud]
    return intra, cross


def round_cost_jax(delivered, cloud_of, aggregator_cloud: int,
                   client_payload, edge_payload, c_intra, c_cross, *,
                   hierarchical: bool = True):
    """$ of one round (Eq. 1/3) as a jnp scalar; prices may be traced
    (dynamic egress schedules index a per-round multiplier array)."""
    intra_b, cross_b = round_bytes_jax(
        delivered, cloud_of, aggregator_cloud, client_payload, edge_payload,
        hierarchical=hierarchical)
    return (intra_b * c_intra + cross_b * c_cross) / _GB


def hierarchical_unit_costs_jax(cloud_of, cloud_sizes, aggregator_cloud: int,
                                c_intra, c_cross):
    """Jittable :meth:`CostModel.hierarchical_unit_costs` — the Eq. 10
    marginal per-client cost with possibly-traced prices (the engine
    recomputes this every round under a price-surge schedule)."""
    cloud_of = jnp.asarray(cloud_of)
    sizes = jnp.asarray(cloud_sizes, jnp.float32)
    k = sizes.shape[0]
    prices = jnp.full((k,), c_cross, jnp.float32
                      ).at[aggregator_cloud].set(c_intra)
    amortized = prices / jnp.maximum(sizes, 1.0)
    return c_intra + amortized[cloud_of]

"""Communication cost model (paper §III-C, Eq. 1–3).

Costs are expressed in $ per round for a model of ``d`` parameters at
``bytes_per_param`` (default fp32 upload, matching the paper's setup).
Prices are $/GB; AWS-style egress defaults are in FLConfig.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.fl_types import CloudTopology

_GB = 1024.0 ** 3


@dataclass(frozen=True)
class CostModel:
    c_intra: float = 0.01     # $/GB within a cloud
    c_cross: float = 0.09     # $/GB cross-cloud egress
    bytes_per_param: int = 4

    def client_unit_costs(self, topo: CloudTopology) -> np.ndarray:
        """c_i (Eq. 2): per-GB price for client i to reach the global
        aggregator's cloud (the FLAT upload path)."""
        same = topo.cloud_of == topo.aggregator_cloud
        return np.where(same, self.c_intra, self.c_cross)

    def hierarchical_unit_costs(self, topo: CloudTopology) -> np.ndarray:
        """Marginal per-client cost under HIERARCHICAL aggregation: every
        client uploads intra-cloud to its edge aggregator; the single
        cross-cloud edge->global upload is amortized over the cloud's
        clients. This is the c_i that Eq. 10 sees inside Cost-TrustFL
        itself — near-uniform, so selection stays reputation-driven and
        clouds are not starved (the cost saving comes from the hierarchy,
        not from abandoning remote clouds)."""
        out = np.full(topo.n_clients, self.c_intra, np.float64)
        for k in range(topo.n_clouds):
            ix = topo.clients_in(k)
            edge_price = (self.c_intra if k == topo.aggregator_cloud
                          else self.c_cross)
            out[ix] += edge_price / max(len(ix), 1)
        return out

    def round_cost(self, topo: CloudTopology, selected: np.ndarray,
                   d_params: int, hierarchical: bool = True) -> float:
        """$ cost of one round (Eq. 1 flat, or the hierarchical variant).

        ``selected``: boolean (N,) participation mask.
        Hierarchical (Eq. 3 structure): every selected client uploads
        intra-cloud to its edge aggregator; each cloud with >=1 selected
        client sends ONE cross-cloud aggregate (clouds co-located with the
        global aggregator pay intra).
        """
        gb = d_params * self.bytes_per_param / _GB
        sel = np.asarray(selected, bool)
        if not hierarchical:
            c = self.client_unit_costs(topo)
            return float(gb * c[sel].sum())
        cost = gb * self.c_intra * sel.sum()          # client -> edge
        for k in range(topo.n_clouds):
            if sel[topo.clients_in(k)].any():
                price = self.c_intra if k == topo.aggregator_cloud else self.c_cross
                cost += gb * price                     # edge -> global
        return float(cost)

    def full_participation_cost(self, topo: CloudTopology, d_params: int) -> float:
        """Eq. 3 upper bound: Σ_k n_k·d·C_intra + K·d·C_cross."""
        gb = d_params * self.bytes_per_param / _GB
        return float(gb * self.c_intra * topo.n_clients +
                     gb * self.c_cross * topo.n_clouds)

    def collective_egress_dollars(self, cross_pod_bytes: int) -> float:
        """Price measured cross-pod collective traffic (from the compiled
        HLO, see repro.roofline) at the egress rate — the TPU-mapping of
        the paper's cross-cloud fee."""
        return cross_pod_bytes / _GB * self.c_cross

from repro.sharding.specs import (batch_specs, cache_specs, data_axes,
                                  param_specs, tree_batch_specs)

__all__ = ["batch_specs", "cache_specs", "data_axes", "param_specs",
           "tree_batch_specs"]

"""Best-effort intermediate sharding constraints.

``constrain(x, {dim: axis})`` applies ``with_sharding_constraint`` against
the *ambient* mesh (jax.set_mesh) when the named axis exists, is free
(auto — not shard_map-manual), and divides the dimension; otherwise it is
a no-op. This lets model code hint GSPMD about fat intermediates (vocab
logits, MoE dispatch buffers) without threading a mesh handle everywhere,
and the same code stays runnable on a single CPU device.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Axis = Union[str, Sequence[str]]


def _usable_axes(mesh, axes: Axis):
    """Filter to axes present on the mesh and not shard_map-manual.
    Returns (names, combined_size)."""
    names = (axes,) if isinstance(axes, str) else tuple(axes)
    out = []
    size = 1
    for a in names:
        if a not in mesh.axis_names:
            continue
        # manual (shard_map) axes cannot be constrained from inside
        try:
            if str(mesh._name_to_type[a]).endswith("Manual"):  # pragma: no cover
                continue
        except Exception:
            pass
        out.append(a)
        size *= mesh.shape[a]
    return tuple(out), size


def constrain(x: jax.Array, dims: Dict[int, Axis]) -> jax.Array:
    """Apply P(...) with ``dims[d] = axis-name(s)`` on dim d, best-effort."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        entries = [None] * x.ndim
        ok = False
        for d, ax in dims.items():
            d = d % x.ndim
            names, size = _usable_axes(mesh, ax)
            if not names or size <= 1:
                continue
            if x.shape[d] % size or x.shape[d] < size:
                continue
            entries[d] = names if len(names) > 1 else names[0]
            ok = True
        if not ok:
            return x
        return jax.lax.with_sharding_constraint(x, P(*entries))
    except Exception:
        return x

"""Partition rules: param/batch/cache PartitionSpecs per (arch x shape x
mesh).

Parameter rule: name-based preferred-dimension lists (Megatron-style:
heads/d_ff/vocab/experts over ``model``), falling back to
largest-divisible-dim; FSDP archs additionally shard one remaining dim
over ``data``. Scan-stacked layer params never shard their leading
(layer) dim. Dims that interact with the RoPE rotate-half trick (head_dim)
are deprioritized.

Batch rule: the client/batch leading dim shards over ('pod','data');
batch-1 decode (long_500k) shards the KV-cache *sequence* dim over
``data`` instead (distributed-cache decode)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

# preferred shard dims per parameter name (indices into the *unscanned*
# shape), tried in order; first divisible wins.
_PREFS: Dict[str, Tuple[int, ...]] = {
    "wq": (1, 0),          # (D, H, hd): heads, then D (row-parallel)
    "wk": (1, 0),
    "wv": (1, 0),
    "wo": (0, 2),          # (H, hd, D)
    "embed": (0, 1),       # (V, D)
    "lm_head": (1, 0),     # (D, V)
    "w_gate": (-1, 0),     # dense (D,F) / moe (E,D,F): last dim = F
    "w_up": (-1, 0),
    "w_down": (-2, -1),    # (F, D) / (E, F, D): F first
    "router": (1, 0),      # (D, E)
    "w_in": (1, 0), "w_out": (0, 1),
    "w_a": (1,), "w_i": (1,),
    "w_r": (1, 0), "w_k": (1, 0), "w_v": (0, 1), "w_o": (0, 1),
    "w_decay1": (0,), "w_decay2": (1,),
}
_MOE_PREFS = {"w_gate": (0, 2), "w_up": (0, 2), "w_down": (0, 1)}


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def _pick(shape: Tuple[int, ...], prefs: Tuple[int, ...], size: int,
          taken: set) -> Optional[int]:
    ndim = len(shape)
    cands = [p % ndim for p in prefs] + sorted(
        range(ndim), key=lambda i: -shape[i])
    for c in cands:
        if c not in taken and shape[c] % size == 0 and shape[c] >= size:
            return c
    return None


def param_specs(params: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    """Pytree of PartitionSpec matching ``params``."""
    model_size = _axis_size(mesh, "model") if "model" in mesh.axis_names else 1
    daxes = data_axes(mesh)
    dsize = int(np.prod([_axis_size(mesh, a) for a in daxes])) if daxes else 1
    moe = cfg.n_experts > 0

    def spec_for(path, leaf) -> P:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        scanned = keys[0] == "scanned"
        shape = tuple(leaf.shape)
        offset = 1 if scanned else 0
        core = shape[offset:]
        if len(core) <= 1 or leaf.size * 4 < 1 << 16:
            return P()                      # small tensors: replicate
        assign: Dict[int, Any] = {}
        taken: set = set()
        prefs = _PREFS.get(name, ())
        if moe and name in _MOE_PREFS and len(core) == 3:
            prefs = _MOE_PREFS[name]
        if model_size > 1:
            m = _pick(core, prefs, model_size, taken)
            if m is not None:
                assign[m] = "model"
                taken.add(m)
        if cfg.fsdp and daxes and dsize > 1:
            d = _pick(core, tuple(p for p in prefs if (p % len(core)) not in taken),
                      dsize, taken)
            if d is not None:
                assign[d] = daxes if len(daxes) > 1 else daxes[0]
                taken.add(d)
        entries = [assign.get(i, None) for i in range(len(core))]
        if scanned:
            entries = [None] + entries
        return P(*entries)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def opt_state_specs(opt_state: Any, params: Any, cfg: ModelConfig,
                    mesh: Mesh) -> Any:
    """ZeRO-1: optimizer moments follow the param sharding PLUS one extra
    dim sharded over the data axes where divisible (the g_global update is
    replicated across data, so each group can own a moment slice)."""
    import dataclasses
    pspecs = param_specs(params, dataclasses.replace(cfg, fsdp=True), mesh)

    def match(path, leaf):
        # OptState(step, mu, nu): mu/nu mirror the param tree
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        if leaf.ndim == 0:
            return P()
        sub = pspecs
        try:
            for k in keys[1:]:
                if isinstance(sub, (list, tuple)):
                    sub = sub[int(k)]
                else:
                    sub = sub[k]
            return sub if isinstance(sub, P) else P()
        except Exception:
            return P()

    return jax.tree_util.tree_map_with_path(match, opt_state)


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch_size: int) -> P:
    """Spec for a (B, ...) batch leaf: shard B over ('pod','data') when
    divisible, else replicate."""
    daxes = data_axes(mesh)
    dsize = int(np.prod([_axis_size(mesh, a) for a in daxes])) if daxes else 1
    if daxes and batch_size % dsize == 0 and batch_size >= dsize:
        return P(daxes if len(daxes) > 1 else daxes[0])
    return P()


def tree_batch_specs(batch: Any, cfg: ModelConfig, mesh: Mesh) -> Any:
    def spec_for(leaf):
        b = leaf.shape[0]
        s = batch_specs(cfg, mesh, b)
        return P(*(list(s) + [None] * (len(leaf.shape) - len(s))))
    return jax.tree.map(spec_for, batch)


def cache_specs(cache: Any, cfg: ModelConfig, mesh: Mesh, batch: int) -> Any:
    """KV caches: shard batch over data axes when divisible; otherwise
    shard the *sequence/state* dim (dim 1 for (B,S,KV,hd) attn caches,
    heads for rwkv state, feature dim for rglru state)."""
    daxes = data_axes(mesh)
    dsize = int(np.prod([_axis_size(mesh, a) for a in daxes])) if daxes else 1
    dax = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    msize = _axis_size(mesh, "model") if "model" in mesh.axis_names else 1

    def spec_for(path, leaf) -> P:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        scanned = keys[0] == "scanned"
        shape = tuple(leaf.shape)
        off = 1 if scanned else 0
        core = shape[off:]
        ent: list = [None] * len(core)
        if name == "pos" or len(core) < 2:
            pass
        elif core[0] % dsize == 0 and core[0] >= dsize and dsize > 1:
            ent[0] = dax                       # batch-sharded
            # additionally shard kv-heads (or head_dim when kv-heads do
            # not divide) over the model axis — a 32k-token cache for an
            # 88-layer model exceeds HBM under batch sharding alone
            if name in ("k", "v") and len(core) == 4 and msize > 1:
                if core[2] % msize == 0 and core[2] >= msize:
                    ent[2] = "model"
                elif core[3] % msize == 0 and core[3] >= msize:
                    ent[3] = "model"
        elif dsize > 1 and len(core) >= 2 and core[1] % dsize == 0 \
                and core[1] >= dsize:
            ent[1] = dax                       # sequence/state-sharded
        if scanned:
            ent = [None] + ent
        return P(*ent)

    return jax.tree_util.tree_map_with_path(spec_for, cache)

"""Pluggable telemetry sinks and the ``Telemetry`` recorder that fans
events out to them.

Sinks are duck-typed: anything with ``emit(event: dict)`` (and
optionally ``close()``) works. Provided:

* :class:`JsonlSink` — one JSON object per line, flushed per event, so
  a crashed run still leaves every emitted round on disk;
* :class:`RingBufferSink` — bounded in-memory buffer (``deque`` with
  ``maxlen``) for interactive inspection and tests;
* :class:`ListSink` — unbounded capture (tests, the report renderer).

``Telemetry`` is a context manager: ``__exit__`` closes every sink even
when the body raised, so the JSONL tail is never lost to an exception
mid-run (flush-on-exception is asserted in tests/test_telemetry.py).
"""
from __future__ import annotations

from collections import deque
from pathlib import Path
from typing import Any, Dict, IO, List, Optional, Union

from repro.telemetry.schema import encode


class JsonlSink:
    """Append events to a JSONL file (or any writable text handle),
    flushing after every line."""

    def __init__(self, path: Union[str, Path, IO[str]]):
        if hasattr(path, "write"):
            self._fh: Optional[IO[str]] = path     # caller-owned handle
            self._owns = False
        else:
            self.path = Path(path)
            self._fh = self.path.open("w")
            self._owns = True

    def emit(self, event: Dict[str, Any]) -> None:
        if self._fh is None:
            raise ValueError("JsonlSink is closed")
        self._fh.write(encode(event) + "\n")
        self._fh.flush()

    def close(self) -> None:
        if self._fh is not None and self._owns:
            self._fh.close()
        self._fh = None

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class RingBufferSink:
    """Keep the last ``capacity`` events in memory."""

    def __init__(self, capacity: int = 256):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self._buf: deque = deque(maxlen=capacity)

    @property
    def capacity(self) -> int:
        return self._buf.maxlen

    @property
    def events(self) -> List[Dict[str, Any]]:
        return list(self._buf)

    def emit(self, event: Dict[str, Any]) -> None:
        self._buf.append(event)

    def close(self) -> None:
        pass


class ListSink:
    """Capture every event (unbounded — tests and renderers)."""

    def __init__(self):
        self.events: List[Dict[str, Any]] = []

    def emit(self, event: Dict[str, Any]) -> None:
        self.events.append(event)

    def close(self) -> None:
        pass


class Telemetry:
    """Multi-sink event recorder. ``emit`` fans out in sink order;
    ``close`` closes every sink (errors in one do not skip the rest)."""

    def __init__(self, *sinks: Any):
        self.sinks = list(sinks)

    @classmethod
    def to_jsonl(cls, path: Union[str, Path]) -> "Telemetry":
        return cls(JsonlSink(path))

    def emit(self, event: Dict[str, Any]) -> None:
        for s in self.sinks:
            s.emit(event)

    def close(self) -> None:
        err: Optional[BaseException] = None
        for s in self.sinks:
            try:
                close = getattr(s, "close", None)
                if close is not None:
                    close()
            except BaseException as e:   # keep closing the rest
                err = err or e
        if err is not None:
            raise err

    def __enter__(self) -> "Telemetry":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

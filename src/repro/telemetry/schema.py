"""Telemetry event schema: the typed per-round record every engine
driver emits, plus the host-side :class:`RunContext` factory that builds
the records.

One schema, three producers. The host loop, the per-round jitted driver
and the ``lax.scan`` stream collector all funnel their raw round outputs
(delivered mask, reputation vector, params-L2 digest) through the SAME
``RunContext.round`` code path, so two engines that agree on the raw
arrays emit byte-identical JSONL lines — the cross-engine parity
contract of ``tests/test_determinism.py``, made queryable. The sharded
engine replays its stacked ``RoundOut`` through the same factory after
the run (its reputation/params match the scan engine to the documented
1e-4, so its digests do too).

Event types (``event`` field):

* ``run_start`` — config echo + optional provenance stamp;
* ``round``     — the per-round record (see ``ROUND_REQUIRED``);
* ``eval``      — accuracy (and optionally loss) when an eval ran;
* ``span``      — a named host-side timing span (compile vs execute);
* ``run_end``   — cumulative totals at shutdown.

``round`` events carry a ``digest`` — cheap scalars (params L2,
reputation L2/sum, a delivered-mask SHA) that fingerprint the
``RoundState`` without shipping it: the seed of the ROADMAP's
tamper-evident round ledger, and an always-on cross-engine diff.

Validation is hand-rolled (:func:`validate_event`) — no jsonschema
dependency; CI runs it over the fast job's JSONL artifact.

v1.1 adds the multi-feature trust fields to ``round`` events:
``trust_features`` (the ``FLConfig.trust_features`` mode, or null) and
``feat_weights`` (the softmax-normalized adaptive feature weights after
this round's EMA update, or null on scalar runs). Both are nullable, so
scalar runs emit the same field *values* across engines and the
byte-parity contract is untouched.
"""
from __future__ import annotations

import hashlib
import json
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost import CostModel
from repro.core.fl_types import CloudTopology

SCHEMA = "cost-trustfl/telemetry/v1.1"

EVENT_TYPES = ("run_start", "round", "eval", "span", "run_end")

ENGINES = ("host", "jit", "shard")

# required fields per event type: name -> allowed python types. ``None``
# entries in _NULLABLE may also be null. ``digest`` is validated
# separately (nested).
_NUM = (int, float)
ROUND_REQUIRED: Dict[str, tuple] = {
    "run_id": (str,), "engine": (str,), "method": (str,), "attack": (str,),
    "seed": (int,), "t": (int,),
    "n_selected": (int,), "n_delivered": (int,), "n_active_malicious": (int,),
    "intra_bytes": _NUM, "cross_bytes": _NUM, "cost": _NUM,
    "cum_cost": _NUM, "cum_intra_bytes": _NUM, "cum_cross_bytes": _NUM,
    "price_mult": _NUM, "compression_ratio": _NUM,
    "rep_mean": _NUM, "rep_min": _NUM, "rep_max": _NUM,
    "digest": (dict,),
}
DIGEST_REQUIRED: Dict[str, tuple] = {
    "params_l2": _NUM, "rep_l2": _NUM, "rep_sum": _NUM,
    "delivered_sha": (str,),
}
_REQUIRED: Dict[str, Dict[str, tuple]] = {
    "run_start": {"run_id": (str,), "engine": (str,), "method": (str,),
                  "attack": (str,), "seed": (int,)},
    "round": ROUND_REQUIRED,
    "eval": {"run_id": (str,), "engine": (str,), "t": (int,),
             "accuracy": _NUM},
    "span": {"name": (str,), "seconds": _NUM},
    "run_end": {"run_id": (str,), "engine": (str,), "rounds_emitted": (int,),
                "cum_cost": _NUM},
}
# nullable optional fields (validated only when present and non-null)
_NULLABLE: Dict[str, tuple] = {
    "scenario": (str,), "rep_honest_mean": _NUM, "rep_malicious_mean": _NUM,
    "loss": _NUM, "rounds": (int,), "config": (dict,), "provenance": (dict,),
    "run_id": (str,), "engine": (str,), "phase": (str,), "t": (int,),
    # v1.1: multi-feature trust path (null on scalar runs, so v1 streams
    # and scalar v1.1 streams stay byte-compatible field-for-field)
    "trust_features": (str,), "feat_weights": (list,),
}


def validate_event(ev: Any) -> List[str]:
    """Schema-check one decoded event; returns error strings (empty =
    valid). Unknown extra fields pass — the schema is open for forward
    compatibility; missing/mistyped required fields fail."""
    errs: List[str] = []
    if not isinstance(ev, dict):
        return [f"event is {type(ev).__name__}, not object"]
    if ev.get("schema") != SCHEMA:
        errs.append(f"schema is {ev.get('schema')!r}, expected {SCHEMA!r}")
    kind = ev.get("event")
    if kind not in EVENT_TYPES:
        errs.append(f"event is {kind!r}, expected one of {EVENT_TYPES}")
        return errs
    for name, types in _REQUIRED[kind].items():
        v = ev.get(name)
        if not isinstance(v, types) or isinstance(v, bool):
            errs.append(f"{kind}.{name}: {v!r} is not {types}")
    if kind == "round" and isinstance(ev.get("digest"), dict):
        for name, types in DIGEST_REQUIRED.items():
            v = ev["digest"].get(name)
            if not isinstance(v, types) or isinstance(v, bool):
                errs.append(f"round.digest.{name}: {v!r} is not {types}")
    if "engine" in ev and ev["engine"] is not None \
            and ev["engine"] not in ENGINES:
        errs.append(f"{kind}.engine: {ev['engine']!r} not in {ENGINES}")
    for name, types in _NULLABLE.items():
        if name in _REQUIRED[kind] or name not in ev or ev[name] is None:
            continue
        if not isinstance(ev[name], types) or isinstance(ev[name], bool):
            errs.append(f"{kind}.{name}: {ev[name]!r} is not {types}")
    if isinstance(ev.get("feat_weights"), list):
        for i, w in enumerate(ev["feat_weights"]):
            if not isinstance(w, _NUM) or isinstance(w, bool):
                errs.append(f"{kind}.feat_weights[{i}]: {w!r} is not {_NUM}")
    return errs


def validate_events(events: Iterable[Any]) -> List[str]:
    """Validate a decoded event stream; errors are prefixed ``#<i>``."""
    errs: List[str] = []
    for i, ev in enumerate(events):
        errs.extend(f"#{i}: {e}" for e in validate_event(ev))
    return errs


def encode(ev: Dict[str, Any]) -> str:
    """The canonical JSONL encoding (insertion-ordered keys, compact
    separators) — byte-stable given equal event dicts."""
    return json.dumps(ev, separators=(",", ":"), allow_nan=False)


def delivered_sha(delivered: np.ndarray) -> str:
    """Short content hash of the delivered mask (bit-packed, so the
    digest is a function of the mask alone, not numpy's memory layout)."""
    packed = np.packbits(np.asarray(delivered, bool))
    return hashlib.sha256(packed.tobytes()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# the event factory

class RunContext:
    """Per-run event factory: holds the static config slice every round
    event needs plus the running totals, and emits to a ``Telemetry``
    recorder (or any object with ``emit(dict)``).

    ``client_payload``/``edge_payload`` are the exact per-link wire
    bytes (``LinkPolicy.payload_vectors``); accounting inside
    :meth:`round` then reproduces ``engine.host_round_accounting``
    float64-exactly — CostModel at the round's surge price over the
    delivered mask — so events agree with ``SimResult`` totals to the
    last bit. Drivers that computed the round's $ themselves (the legacy
    host loop under host-hook pricing) pass explicit overrides instead.
    """

    def __init__(self, telemetry: Any, *, engine: str, run_id: str,
                 method: str, attack: str, seed: int,
                 topo: CloudTopology, d_params: int, hierarchical: bool,
                 m_selected: int, malicious: np.ndarray,
                 client_payload: Optional[np.ndarray] = None,
                 edge_payload: Optional[np.ndarray] = None,
                 c_intra: float = 0.01, c_cross: float = 0.09,
                 price_multipliers: Sequence[float] = (1.0,),
                 malice_warmup: int = 0,
                 scenario: Optional[str] = None,
                 trust_features: Optional[str] = None):
        self.telemetry = telemetry
        self.engine = engine
        self.run_id = run_id
        self.method = method
        self.attack = attack
        self.scenario = scenario
        self.trust_features = trust_features
        self.seed = int(seed)
        self.topo = topo
        self.d_params = int(d_params)
        self.hierarchical = bool(hierarchical)
        self.m_selected = int(m_selected)
        self.malicious = np.asarray(malicious, bool)
        self.client_payload = client_payload
        self.edge_payload = edge_payload
        self.c_intra = float(c_intra)
        self.c_cross = float(c_cross)
        self.price_multipliers = tuple(float(m) for m in price_multipliers)
        self.malice_warmup = int(malice_warmup)
        self.cum_cost = 0.0
        self.cum_intra = 0.0
        self.cum_cross = 0.0
        self.rounds_emitted = 0

    # -- emission -----------------------------------------------------------
    def _emit(self, ev: Dict[str, Any]) -> Dict[str, Any]:
        if self.telemetry is not None:
            self.telemetry.emit(ev)
        return ev

    def _base(self, event: str) -> Dict[str, Any]:
        return {"schema": SCHEMA, "event": event, "run_id": self.run_id,
                "engine": self.engine}

    def run_start(self, *, rounds: Optional[int] = None,
                  config: Optional[Dict[str, Any]] = None,
                  provenance: Optional[Dict[str, Any]] = None
                  ) -> Dict[str, Any]:
        ev = self._base("run_start")
        ev.update(method=self.method, attack=self.attack,
                  scenario=self.scenario, seed=self.seed, rounds=rounds,
                  config=config, provenance=provenance)
        return self._emit(ev)

    def _account(self, t: int, delivered: np.ndarray
                 ) -> Tuple[float, float, float, float]:
        """(cost, intra_bytes, cross_bytes, price_mult) at this round's
        surge price — the same float64 reduction as
        ``engine.host_round_accounting`` (one delivered row, t0=t)."""
        mults = self.price_multipliers
        mult = mults[t % len(mults)]
        cm = CostModel(self.c_intra, self.c_cross * mult)
        intra_b, cross_b = cm.round_bytes(
            self.topo, delivered, self.d_params,
            hierarchical=self.hierarchical,
            client_payload=self.client_payload,
            edge_payload=self.edge_payload)
        cost = cm.round_cost(
            self.topo, delivered, self.d_params,
            hierarchical=self.hierarchical,
            client_payload=self.client_payload,
            edge_payload=self.edge_payload)
        return float(cost), float(intra_b), float(cross_b), float(mult)

    def round(self, t: int, delivered: np.ndarray, rep: np.ndarray,
              params_l2: float, *, cost: Optional[float] = None,
              intra_bytes: Optional[float] = None,
              cross_bytes: Optional[float] = None,
              price_mult: Optional[float] = None,
              feat_weights: Optional[np.ndarray] = None) -> Dict[str, Any]:
        """Build + emit one ``round`` event from the raw round outputs.

        ``delivered``/``rep`` are the (N,) mask and post-update
        reputation; ``params_l2`` the in-graph state digest
        (``RoundOut.params_l2``). Accounting defaults to the internal
        float64 path; explicit ``cost``/bytes override it (legacy host
        loop under host-hook pricing, where only the driver knows the
        mutated prices)."""
        t = int(t)
        delivered = np.asarray(delivered, bool)
        rep = np.asarray(rep)
        if cost is None or intra_bytes is None or cross_bytes is None:
            cost, intra_bytes, cross_bytes, mult = self._account(t, delivered)
        else:
            mults = self.price_multipliers
            mult = (float(price_mult) if price_mult is not None
                    else mults[t % len(mults)])
        self.cum_cost += cost
        self.cum_intra += intra_bytes
        self.cum_cross += cross_bytes
        self.rounds_emitted += 1

        # compression ratio: billed bytes vs the same mask shipped as
        # dense fp32 (payload=None defaults in CostModel)
        dense_i, dense_c = CostModel(self.c_intra, self.c_cross).round_bytes(
            self.topo, delivered, self.d_params,
            hierarchical=self.hierarchical)
        dense = dense_i + dense_c
        ratio = (intra_bytes + cross_bytes) / dense if dense > 0 else 1.0

        active_mal = (self.malicious if t >= self.malice_warmup
                      else np.zeros_like(self.malicious))
        hon = ~self.malicious
        rep64 = rep.astype(np.float64)
        ev = self._base("round")
        ev.update(
            method=self.method, attack=self.attack, scenario=self.scenario,
            seed=self.seed, t=t,
            n_selected=self.m_selected,
            n_delivered=int(delivered.sum()),
            n_active_malicious=int((active_mal & delivered).sum()),
            intra_bytes=float(intra_bytes), cross_bytes=float(cross_bytes),
            cost=float(cost), cum_cost=self.cum_cost,
            cum_intra_bytes=self.cum_intra, cum_cross_bytes=self.cum_cross,
            price_mult=float(mult), compression_ratio=float(ratio),
            rep_mean=float(rep64.mean()), rep_min=float(rep64.min()),
            rep_max=float(rep64.max()),
            rep_honest_mean=(float(rep64[hon].mean()) if hon.any()
                             else None),
            rep_malicious_mean=(float(rep64[self.malicious].mean())
                                if self.malicious.any() else None),
            trust_features=self.trust_features,
            feat_weights=(None if feat_weights is None
                          else [float(w) for w in np.asarray(feat_weights)]),
            digest={"params_l2": float(params_l2),
                    "rep_l2": float(np.linalg.norm(rep64)),
                    "rep_sum": float(rep64.sum()),
                    "delivered_sha": delivered_sha(delivered)})
        return self._emit(ev)

    def eval(self, t: int, accuracy: float,
             loss: Optional[float] = None) -> Dict[str, Any]:
        ev = self._base("eval")
        ev.update(t=int(t), accuracy=float(accuracy),
                  loss=None if loss is None else float(loss))
        return self._emit(ev)

    def span(self, name: str, seconds: float, *,
             phase: Optional[str] = None,
             t: Optional[int] = None) -> Dict[str, Any]:
        ev = self._base("span")
        ev.update(name=name, seconds=float(seconds), phase=phase,
                  t=None if t is None else int(t))
        return self._emit(ev)

    def run_end(self) -> Dict[str, Any]:
        ev = self._base("run_end")
        ev.update(rounds_emitted=self.rounds_emitted,
                  cum_cost=self.cum_cost, cum_intra_bytes=self.cum_intra,
                  cum_cross_bytes=self.cum_cross)
        return self._emit(ev)

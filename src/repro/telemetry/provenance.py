"""Provenance stamps: make every artifact traceable to a commit and a
host. ``benchmarks/*`` embed :func:`stamp` in their ``BENCH_*.json``
and drivers attach it to ``run_start`` events, so a number in an
artifact can always be tied to (code version, machine class, runtime).
"""
from __future__ import annotations

import platform
import subprocess
import sys
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax

_REPO_ROOT = Path(__file__).resolve().parents[3]


def _git(*args: str) -> Optional[str]:
    try:
        out = subprocess.run(["git", *args], cwd=_REPO_ROOT,
                             capture_output=True, text=True, timeout=10)
        return out.stdout.strip() if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def stamp() -> Dict[str, Any]:
    """Commit + host + runtime provenance (every field best-effort:
    outside a git checkout the git keys are null, never an exception)."""
    sha = _git("rev-parse", "HEAD")
    status = _git("status", "--porcelain")
    devices = jax.devices()
    return {
        "git_sha": sha,
        "git_dirty": bool(status) if status is not None else None,
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "machine": platform.machine(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "n_devices": len(devices),
        "device_kind": devices[0].device_kind if devices else None,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }

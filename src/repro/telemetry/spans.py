"""Host-side timing spans and profiler hooks.

Two annotation layers:

* inside jitted code, ``jax.named_scope`` labels the round phases
  (``engine.round_step`` wraps select/train/attack/compress/aggregate/
  account) — the names show up in jaxprs, HLO metadata and profiler
  traces, and cost nothing at runtime;
* on the host, :func:`span` wraps a block in
  ``jax.profiler.TraceAnnotation`` (visible in Perfetto) AND times it
  with ``perf_counter``, optionally emitting a ``span`` event — this is
  how drivers separate compile (first call) from steady-state execute.

:func:`trace` is the opt-in Perfetto capture: wrap any driver call and
point ``jax.profiler``'s trace at a directory, then load the dump at
``ui.perfetto.dev``.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Any, Iterator, Optional

import jax


class SpanTimer:
    """Mutable result handle yielded by :func:`span` (``seconds`` is
    populated when the block exits)."""

    def __init__(self, name: str):
        self.name = name
        self.seconds: float = 0.0


@contextmanager
def span(name: str, context: Optional[Any] = None, *,
         phase: Optional[str] = None,
         t: Optional[int] = None) -> Iterator[SpanTimer]:
    """Time a host-side block under a profiler ``TraceAnnotation``.

    ``context`` — an optional ``schema.RunContext``: when given, a
    ``span`` event is emitted on exit (even if the block raised, so a
    crashing round still records how far it got)."""
    timer = SpanTimer(name)
    t0 = time.perf_counter()
    try:
        with jax.profiler.TraceAnnotation(name):
            yield timer
    finally:
        timer.seconds = time.perf_counter() - t0
        if context is not None:
            context.span(name, timer.seconds, phase=phase, t=t)


def start_trace(logdir: str) -> None:
    """Start a Perfetto-compatible profiler capture into ``logdir``."""
    jax.profiler.start_trace(logdir)


def stop_trace() -> None:
    jax.profiler.stop_trace()


@contextmanager
def trace(logdir: str) -> Iterator[None]:
    """Capture a Perfetto trace of the ``with`` body into ``logdir``."""
    start_trace(logdir)
    try:
        yield
    finally:
        stop_trace()

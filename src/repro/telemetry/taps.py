"""In-graph event taps: stream per-round outputs out of a jitted
``lax.scan`` while it runs.

:func:`instrument` wraps an engine ``round_step`` with an **ordered**
``jax.debug.callback`` that hands ``(t, RoundOut)`` to the host after
every round — so a T-round device call reports live instead of going
dark until the final block. The callback targets the module-level
:func:`_dispatch` trampoline; the actual consumer is installed at *run*
time with :func:`collecting`, so one compiled executable serves every
run (and costs a no-op host call per round when nothing is listening).

Compiles to nothing when disabled: ``instrument(step, None)`` and
``instrument(step, TapSpec(enabled=False))`` return ``round_step``
itself, and ``engine.compiled`` normalizes a disabled tap to the
untapped cache entry — off and absent are the SAME executable, so the
lowered HLO is identical by construction (asserted in
tests/test_telemetry.py). The tap is therefore a compile-time choice —
only an *enabled* tap builds a separate executable.

Ordered callbacks cannot cross ``vmap``: the vmapped multi-seed batch
drivers always run untapped (their per-round events are replayed from
the stacked ``RoundOut`` after the run).
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax

# the current consumer: (t, out) -> None. Installed by `collecting`;
# single-threaded use (matching the rest of the engine drivers).
_collector: Optional[Callable[[Any, Any], None]] = None


@dataclass(frozen=True)
class TapSpec:
    """Hashable tap configuration — part of the engine compile key."""
    enabled: bool = True


def _dispatch(t, out) -> None:
    """The baked-in callback target: forwards to the installed
    collector, no-op otherwise. ``t`` and ``out`` arrive as host numpy
    arrays (``out`` keeps its ``RoundOut`` pytree structure)."""
    if _collector is not None:
        _collector(t, out)


@contextmanager
def collecting(fn: Callable[[Any, Any], None]):
    """Install ``fn`` as the tap consumer for the duration of the
    ``with`` body (restores the previous consumer on exit).

    Exit waits on ``jax.effects_barrier()`` BEFORE uninstalling ``fn``:
    callback dispatch is asynchronous, so without the barrier the tail
    of a run could fire after the consumer is gone."""
    global _collector
    prev = _collector
    _collector = fn
    try:
        yield
    finally:
        try:
            jax.effects_barrier()
        finally:
            _collector = prev


def instrument(round_step: Callable, tap: Optional[TapSpec]) -> Callable:
    """``round_step`` with an ordered per-round event tap, or the
    original function unchanged when the tap is off/absent."""
    if tap is None or not tap.enabled:
        return round_step

    def tapped_step(state, data, t):
        new_state, out = round_step(state, data, t)
        jax.debug.callback(_dispatch, t, out, ordered=True)
        return new_state, out

    return tapped_step

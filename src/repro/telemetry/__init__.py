"""Unified telemetry: typed per-round event streams, profiler spans and
state digests across all three round engines (host loop, ``lax.scan``
engine, mesh-sharded engine).

Quick start::

    from repro.telemetry import Telemetry
    from repro.federated import run_simulation

    with Telemetry.to_jsonl("events.jsonl") as tel:
        run_simulation(flcfg, rounds=20, telemetry=tel)

then ``python -m repro.telemetry.report events.jsonl``.

Layout: ``schema`` (event types + the ``RunContext`` factory +
validation), ``sinks`` (JSONL / ring buffer / recorder), ``taps``
(ordered ``jax.debug.callback`` streaming out of jitted scans — zero
ops when disabled), ``spans`` (TraceAnnotation timing + Perfetto
capture), ``provenance`` (git/host stamps), ``report`` (validation CLI
+ wire-breakdown tables from events alone).
"""
from repro.telemetry.provenance import stamp
from repro.telemetry.schema import (ENGINES, EVENT_TYPES, SCHEMA,
                                    RunContext, delivered_sha, encode,
                                    validate_event, validate_events)
from repro.telemetry.sinks import (JsonlSink, ListSink, RingBufferSink,
                                   Telemetry)
from repro.telemetry.spans import span, start_trace, stop_trace, trace
from repro.telemetry.taps import TapSpec, collecting, instrument

__all__ = [
    "SCHEMA", "EVENT_TYPES", "ENGINES", "RunContext", "delivered_sha",
    "encode", "validate_event", "validate_events",
    "Telemetry", "JsonlSink", "RingBufferSink", "ListSink",
    "TapSpec", "collecting", "instrument",
    "span", "trace", "start_trace", "stop_trace", "stamp",
]

"""Telemetry report: validate a JSONL event stream and render summary
tables from events alone.

The wire-breakdown renderer is the single formatting path for
per-round byte/$ tables: ``examples/cost_report.py`` builds its FL
breakdown through it (from synthesized events), and the same table
falls out of any recorded run —

    PYTHONPATH=src python -m repro.telemetry.report events.jsonl

CI runs ``--validate-only`` over the fast job's JSONL artifact, so
event-format drift fails the build (exit 1 on any schema violation).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

from repro.telemetry.schema import validate_events

MB = 1024.0 ** 2


def load_events(path: Union[str, Path]) -> List[Dict[str, Any]]:
    """Decode a JSONL event file (raises on malformed JSON, with the
    offending line number)."""
    events = []
    with Path(path).open() as fh:
        for i, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i}: not valid JSON: {e}") from e
    return events


def wire_breakdown(events: Iterable[Dict[str, Any]],
                   label_key: str = "run_id") -> List[Dict[str, Any]]:
    """Per-run wire/cost rows from ``round`` events alone: mean
    intra/cross bytes and $ per round, mean compression ratio. Rows
    appear in first-emission order of their label."""
    rows: Dict[str, Dict[str, Any]] = {}
    for ev in events:
        if ev.get("event") != "round":
            continue
        label = str(ev.get(label_key))
        r = rows.setdefault(label, {
            "label": label, "engine": ev.get("engine"),
            "method": ev.get("method"), "rounds": 0,
            "intra_bytes": 0.0, "cross_bytes": 0.0, "cost": 0.0,
            "compression_ratio": 0.0})
        r["rounds"] += 1
        r["intra_bytes"] += ev["intra_bytes"]
        r["cross_bytes"] += ev["cross_bytes"]
        r["cost"] += ev["cost"]
        r["compression_ratio"] += ev["compression_ratio"]
    out = []
    for r in rows.values():
        n = r["rounds"]
        out.append({**r,
                    "intra_bytes": r["intra_bytes"] / n,
                    "cross_bytes": r["cross_bytes"] / n,
                    "cost": r["cost"] / n,
                    "compression_ratio": r["compression_ratio"] / n})
    return out


def render_wire_table(rows: Sequence[Dict[str, Any]],
                      label_header: str = "run") -> str:
    """The wire-breakdown table (per-round means; ``cross vs first``
    compares each row's cross bytes against the first row's — the
    uncompressed baseline when the caller orders it first)."""
    lines = [f"{label_header:26s}{'intra MB':>10s}{'cross MB':>10s}"
             f"{'$/round':>10s}{'cross vs first':>15s}",
             "-" * 71]
    base_cross = None
    for r in rows:
        base_cross = base_cross if base_cross is not None \
            else r["cross_bytes"]
        ratio = base_cross / max(r["cross_bytes"], 1.0)
        lines.append(f"{r['label'][:26]:26s}{r['intra_bytes'] / MB:10.2f}"
                     f"{r['cross_bytes'] / MB:10.2f}{r['cost']:10.6f}"
                     f"{ratio:14.2f}x")
    return "\n".join(lines)


def summarize(events: Sequence[Dict[str, Any]]) -> str:
    """One-paragraph stream summary (counts per event type, runs seen,
    final cumulative $ per run)."""
    counts: Dict[str, int] = {}
    finals: Dict[str, float] = {}
    for ev in events:
        counts[ev.get("event", "?")] = counts.get(ev.get("event", "?"), 0) + 1
        if ev.get("event") == "round":
            finals[str(ev.get("run_id"))] = ev.get("cum_cost", 0.0)
    parts = [f"{len(events)} events "
             f"({', '.join(f'{k}={v}' for k, v in sorted(counts.items()))})"]
    for run, cost in finals.items():
        parts.append(f"  {run}: cum_cost=${cost:.6f}")
    return "\n".join(parts)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="telemetry JSONL file")
    ap.add_argument("--validate-only", action="store_true",
                    help="schema-check only; exit 1 on any violation")
    args = ap.parse_args(argv)

    events = load_events(args.path)
    errors = validate_events(events)
    if errors:
        print(f"SCHEMA INVALID ({len(errors)} violations):",
              file=sys.stderr)
        for e in errors[:50]:
            print(f"  {e}", file=sys.stderr)
        return 1
    if args.validate_only:
        print(f"{args.path}: {len(events)} events, schema OK")
        return 0

    print(summarize(events))
    rows = wire_breakdown(events)
    if rows:
        print()
        print(render_wire_table(rows))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Non-IID federated partitioning: Dirichlet(α) label-skew split
(paper §V-A, [Zhao et al. 2018]) plus natural-user splits for
FEMNIST-style data."""
from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        seed: int = 0, min_size: int = 8) -> List[np.ndarray]:
    """Returns per-client index arrays; class proportions per client are
    drawn from Dirichlet(α) — lower α, more heterogeneity."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    while True:
        idx_per_client: List[List[int]] = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx_c = np.nonzero(labels == c)[0]
            rng.shuffle(idx_c)
            props = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for i, part in enumerate(np.split(idx_c, cuts)):
                idx_per_client[i].extend(part.tolist())
        sizes = [len(ix) for ix in idx_per_client]
        if min(sizes) >= min_size:
            break
        alpha *= 1.5   # retry with slightly more uniformity to avoid empties
    return [np.array(sorted(ix)) for ix in idx_per_client]


def iid_partition(n: int, n_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [np.sort(p) for p in np.array_split(perm, n_clients)]

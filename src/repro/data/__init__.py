from repro.data.partition import dirichlet_partition, iid_partition
from repro.data.pipeline import FederatedData, build_federated, token_batches
from repro.data.synthetic import (ImageDataset, make_cifar10_like,
                                  make_femnist_like, make_token_stream)

__all__ = ["dirichlet_partition", "iid_partition", "FederatedData",
           "build_federated", "token_batches", "ImageDataset",
           "make_cifar10_like", "make_femnist_like", "make_token_stream"]

"""Synthetic dataset surrogates (the container is offline; see DESIGN.md
§2.2). Class-conditional Gaussian-mixture images at the original
resolutions/class counts so non-IID partitioning, label-flipping, and
classifier learning behave like the real benchmarks, plus token streams
for LLM federation."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class ImageDataset:
    x: np.ndarray          # (N, H, W, C) float32 in [0,1]-ish
    y: np.ndarray          # (N,) int64
    n_classes: int
    name: str


def _class_conditional_images(rng: np.random.Generator, n: int,
                              shape: Tuple[int, int, int], n_classes: int,
                              n_prototypes: int = 3, noise: float = 0.35
                              ) -> Tuple[np.ndarray, np.ndarray]:
    """Each class = a mixture of smooth low-frequency prototypes + noise.
    Learnable by a small CNN but far from trivially separable."""
    h, w, c = shape
    y = rng.integers(0, n_classes, size=n)
    # low-frequency prototypes: random coefficients over a coarse grid,
    # upsampled by repetition
    coarse = 4
    protos = rng.normal(0, 1, size=(n_classes, n_prototypes, coarse, coarse, c))
    reps_h, reps_w = h // coarse + 1, w // coarse + 1
    protos_full = np.repeat(np.repeat(protos, reps_h, axis=2), reps_w, axis=3)
    protos_full = protos_full[:, :, :h, :w, :]
    which = rng.integers(0, n_prototypes, size=n)
    x = protos_full[y, which] + noise * rng.normal(0, 1, size=(n, h, w, c))
    x = (x - x.min()) / (x.max() - x.min() + 1e-9)
    return x.astype(np.float32), y.astype(np.int64)


def make_cifar10_like(n: int = 12000, seed: int = 0) -> ImageDataset:
    rng = np.random.default_rng(seed)
    x, y = _class_conditional_images(rng, n, (32, 32, 3), 10)
    return ImageDataset(x, y, 10, "synth-cifar10")


def make_femnist_like(n: int = 16000, seed: int = 0) -> ImageDataset:
    rng = np.random.default_rng(seed)
    x, y = _class_conditional_images(rng, n, (28, 28, 1), 62)
    return ImageDataset(x, y, 62, "synth-femnist")


def make_token_stream(n_tokens: int, vocab: int, seed: int = 0,
                      order: int = 2) -> np.ndarray:
    """Markov-ish synthetic token stream so an LM has learnable structure."""
    rng = np.random.default_rng(seed)
    # sparse bigram transition structure over a reduced state space
    n_states = min(vocab, 256)
    trans = rng.integers(0, n_states, size=(n_states, 8))
    toks = np.empty(n_tokens, np.int32)
    s = 0
    for i in range(n_tokens):
        s = int(trans[s, rng.integers(0, 8)])
        toks[i] = s if rng.random() > 0.05 else int(rng.integers(0, vocab))
    return toks

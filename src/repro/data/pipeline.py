"""Federated data pipeline: builds fixed-size per-client sample tensors
(so client datasets stack into jittable (N_clients, n_samples, ...) arrays
for vmap'd local training) + a reference dataset per cloud (FLTrust-style
trust anchor), and token-stream batching for LLM training."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Tuple

import numpy as np

from repro.core.fl_types import CloudTopology
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import ImageDataset


@dataclass(frozen=True)
class FederatedData:
    client_x: np.ndarray      # (N, S, ...) fixed-size per-client samples
    client_y: np.ndarray      # (N, S)
    ref_x: np.ndarray         # (K, R, ...) per-cloud reference sets
    ref_y: np.ndarray         # (K, R)
    test_x: np.ndarray
    test_y: np.ndarray
    n_classes: int


def build_federated(ds: ImageDataset, topo: CloudTopology, *,
                    alpha: float = 0.5, samples_per_client: int = 96,
                    ref_samples: int = 100, test_frac: float = 0.15,
                    seed: int = 0) -> FederatedData:
    rng = np.random.default_rng(seed)
    n = len(ds.y)
    n_test = int(n * test_frac)
    perm = rng.permutation(n)
    test_ix, pool_ix = perm[:n_test], perm[n_test:]

    # reference pools: clean IID samples per cloud (the paper's 100-sample
    # trusted set at each edge aggregator)
    ref_ix = pool_ix[: topo.n_clouds * ref_samples].reshape(
        topo.n_clouds, ref_samples)
    train_ix = pool_ix[topo.n_clouds * ref_samples:]

    parts = dirichlet_partition(ds.y[train_ix], topo.n_clients, alpha,
                                seed=seed)
    s = samples_per_client
    cx = np.empty((topo.n_clients, s) + ds.x.shape[1:], np.float32)
    cy = np.empty((topo.n_clients, s), np.int64)
    for i, p in enumerate(parts):
        ix = train_ix[p]
        take = rng.choice(ix, size=s, replace=len(ix) < s)
        cx[i], cy[i] = ds.x[take], ds.y[take]
    return FederatedData(
        client_x=cx, client_y=cy,
        ref_x=ds.x[ref_ix], ref_y=ds.y[ref_ix],
        test_x=ds.x[test_ix], test_y=ds.y[test_ix],
        n_classes=ds.n_classes)


def token_batches(stream: np.ndarray, batch: int, seq: int, seed: int = 0
                  ) -> Iterator[np.ndarray]:
    """Infinite iterator of (batch, seq+1) token windows."""
    rng = np.random.default_rng(seed)
    n = len(stream) - seq - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        yield np.stack([stream[s: s + seq + 1] for s in starts])

"""End-to-end simulation harness reproducing the paper's experimental
protocol (3 clouds x 30 clients, Dirichlet non-IID, 4 attacks,
6 methods)."""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Union

import numpy as np

from repro.configs.base import FLConfig
from repro.core.fl_types import CloudTopology
from repro.data.pipeline import FederatedData, build_federated
from repro.data.synthetic import make_cifar10_like, make_femnist_like
from repro.federated.server import FLServer
from repro.scenarios import Scenario, get_scenario

ScenarioLike = Union[str, Scenario, None]


@dataclass
class SimResult:
    method: str
    attack: str
    accuracy: List[float]
    rounds: List[int]
    final_accuracy: Optional[float]   # None when no eval ran (rounds=0)
    total_cost: float
    reputation: Optional[np.ndarray] = None
    malicious: Optional[np.ndarray] = None
    intra_bytes: float = 0.0          # cumulative wire bytes, intra-class
    cross_bytes: float = 0.0          # cumulative wire bytes, cross-cloud
    scenario: Optional[str] = None    # registry name when one was run


def make_topology(flcfg: FLConfig) -> CloudTopology:
    return CloudTopology.even(flcfg.n_clouds, flcfg.clients_per_cloud)


def make_data(flcfg: FLConfig, dataset: str = "cifar10", seed: int = 0,
              n_samples: int = 12000, samples_per_client: int = 96
              ) -> FederatedData:
    topo = make_topology(flcfg)
    ds = (make_cifar10_like(n_samples, seed) if dataset == "cifar10"
          else make_femnist_like(n_samples, seed))
    return build_federated(ds, topo, alpha=flcfg.dirichlet_alpha,
                           samples_per_client=samples_per_client,
                           ref_samples=flcfg.ref_samples, seed=seed)


def _resolve_scenario(scenario: ScenarioLike) -> Optional[Scenario]:
    return get_scenario(scenario) if isinstance(scenario, str) else scenario


def run_simulation(flcfg: FLConfig, *, method: Optional[str] = None,
                   scenario: ScenarioLike = None,
                   dataset: str = "cifar10", rounds: Optional[int] = None,
                   eval_every: int = 5, seed: int = 0,
                   data: Optional[FederatedData] = None,
                   verbose: bool = False) -> SimResult:
    """Run one (method, scenario) simulation.

    ``scenario`` — a ``repro.scenarios`` registry name or ``Scenario``:
    its FLConfig overrides are applied first (idempotent, so callers that
    already applied them can pass both) and its hooks ride along on the
    server. ``method`` defaults to ``flcfg.aggregator``; an explicit
    argument wins over the config field.
    """
    scenario = _resolve_scenario(scenario)
    if scenario is not None:
        flcfg = scenario.apply(flcfg)
    method = flcfg.aggregator if method is None else method
    rounds = rounds if rounds is not None else flcfg.rounds
    topo = make_topology(flcfg)
    data = data if data is not None else make_data(flcfg, dataset, seed)
    server = FLServer(flcfg, topo, data, method=method, seed=seed,
                      scenario=scenario)

    accs, ticks = [], []
    for t in range(rounds):
        server.run_round(t)
        if (t + 1) % eval_every == 0 or t == rounds - 1:
            acc = server.evaluate()
            accs.append(acc)
            ticks.append(t + 1)
            if verbose:
                print(f"[{method}/{flcfg.attack}] round {t+1:4d} "
                      f"acc={acc:.4f} cum_cost=${server.cum_cost:.4f}")
    # rounds=0 yields no evals -> final_accuracy None. FLServer always
    # carries rep today; the getattr keeps SimResult construction working
    # for server implementations without reputation state.
    rep = getattr(server, "rep", None)
    return SimResult(method=method, attack=flcfg.attack, accuracy=accs,
                     rounds=ticks,
                     final_accuracy=accs[-1] if accs else None,
                     total_cost=server.cum_cost,
                     reputation=(np.array(rep.ema) if rep is not None
                                 else None),
                     malicious=server.malicious,
                     intra_bytes=server.cum_intra_bytes,
                     cross_bytes=server.cum_cross_bytes,
                     scenario=scenario.name if scenario is not None else None)


def compare_methods(flcfg: FLConfig, methods: List[str], *,
                    scenario: ScenarioLike = None,
                    dataset: str = "cifar10", rounds: int = 30,
                    seed: int = 0, verbose: bool = False
                    ) -> Dict[str, SimResult]:
    """Run every method on ONE dataset/scenario so comparisons are
    apples-to-apples (shared data partition, shared scenario hooks)."""
    scenario = _resolve_scenario(scenario)
    if scenario is not None:
        flcfg = scenario.apply(flcfg)   # before make_data: overrides may
    data = make_data(flcfg, dataset, seed)  # change topology/partition
    return {m: run_simulation(flcfg, method=m, scenario=scenario,
                              dataset=dataset, rounds=rounds, seed=seed,
                              data=data, verbose=verbose)
            for m in methods}

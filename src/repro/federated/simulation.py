"""End-to-end simulation harness reproducing the paper's experimental
protocol (3 clouds x 30 clients, Dirichlet non-IID, 4 attacks,
6 methods)."""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import FLConfig
from repro.core.fl_types import CloudTopology
from repro.data.pipeline import FederatedData, build_federated
from repro.data.synthetic import make_cifar10_like, make_femnist_like
from repro.federated.server import FLServer


@dataclass
class SimResult:
    method: str
    attack: str
    accuracy: List[float]
    rounds: List[int]
    final_accuracy: Optional[float]   # None when no eval ran (rounds=0)
    total_cost: float
    reputation: Optional[np.ndarray] = None
    malicious: Optional[np.ndarray] = None
    intra_bytes: float = 0.0          # cumulative wire bytes, intra-class
    cross_bytes: float = 0.0          # cumulative wire bytes, cross-cloud


def make_topology(flcfg: FLConfig) -> CloudTopology:
    return CloudTopology.even(flcfg.n_clouds, flcfg.clients_per_cloud)


def make_data(flcfg: FLConfig, dataset: str = "cifar10", seed: int = 0,
              n_samples: int = 12000, samples_per_client: int = 96
              ) -> FederatedData:
    topo = make_topology(flcfg)
    ds = (make_cifar10_like(n_samples, seed) if dataset == "cifar10"
          else make_femnist_like(n_samples, seed))
    return build_federated(ds, topo, alpha=flcfg.dirichlet_alpha,
                           samples_per_client=samples_per_client,
                           ref_samples=flcfg.ref_samples, seed=seed)


def run_simulation(flcfg: FLConfig, *, method: str = "cost_trustfl",
                   dataset: str = "cifar10", rounds: Optional[int] = None,
                   eval_every: int = 5, seed: int = 0,
                   data: Optional[FederatedData] = None,
                   verbose: bool = False) -> SimResult:
    rounds = rounds if rounds is not None else flcfg.rounds
    topo = make_topology(flcfg)
    data = data if data is not None else make_data(flcfg, dataset, seed)
    server = FLServer(flcfg, topo, data, method=method, seed=seed)

    accs, ticks = [], []
    for t in range(rounds):
        server.run_round(t)
        if (t + 1) % eval_every == 0 or t == rounds - 1:
            acc = server.evaluate()
            accs.append(acc)
            ticks.append(t + 1)
            if verbose:
                print(f"[{method}/{flcfg.attack}] round {t+1:4d} "
                      f"acc={acc:.4f} cum_cost=${server.cum_cost:.4f}")
    # rounds=0 yields no evals -> final_accuracy None. FLServer always
    # carries rep today; the getattr keeps SimResult construction working
    # for server implementations without reputation state.
    rep = getattr(server, "rep", None)
    return SimResult(method=method, attack=flcfg.attack, accuracy=accs,
                     rounds=ticks,
                     final_accuracy=accs[-1] if accs else None,
                     total_cost=server.cum_cost,
                     reputation=(np.array(rep.ema) if rep is not None
                                 else None),
                     malicious=server.malicious,
                     intra_bytes=server.cum_intra_bytes,
                     cross_bytes=server.cum_cross_bytes)


def compare_methods(flcfg: FLConfig, methods: List[str], *,
                    dataset: str = "cifar10", rounds: int = 30,
                    seed: int = 0, verbose: bool = False
                    ) -> Dict[str, SimResult]:
    data = make_data(flcfg, dataset, seed)
    return {m: run_simulation(flcfg, method=m, dataset=dataset,
                              rounds=rounds, seed=seed, data=data,
                              verbose=verbose)
            for m in methods}

"""End-to-end simulation harness reproducing the paper's experimental
protocol (3 clouds x 30 clients, Dirichlet non-IID, 4 attacks,
6 methods)."""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.fl_types import CloudTopology
from repro.data.pipeline import FederatedData, build_federated
from repro.data.synthetic import make_cifar10_like, make_femnist_like
from repro.federated import client as client_mod
from repro.federated import engine as engine_mod
from repro.federated.server import FLServer
from repro.scenarios import Scenario, get_scenario

ScenarioLike = Union[str, Scenario, None]


@dataclass
class SimResult:
    method: str
    attack: str
    accuracy: List[float]
    rounds: List[int]
    final_accuracy: Optional[float]   # None when no eval ran (rounds=0)
    total_cost: float
    reputation: Optional[np.ndarray] = None
    malicious: Optional[np.ndarray] = None
    intra_bytes: float = 0.0          # cumulative wire bytes, intra-class
    cross_bytes: float = 0.0          # cumulative wire bytes, cross-cloud
    scenario: Optional[str] = None    # registry name when one was run


def make_topology(flcfg: FLConfig) -> CloudTopology:
    return CloudTopology.even(flcfg.n_clouds, flcfg.clients_per_cloud)


def make_data(flcfg: FLConfig, dataset: str = "cifar10", seed: int = 0,
              n_samples: int = 12000, samples_per_client: int = 96
              ) -> FederatedData:
    topo = make_topology(flcfg)
    ds = (make_cifar10_like(n_samples, seed) if dataset == "cifar10"
          else make_femnist_like(n_samples, seed))
    return build_federated(ds, topo, alpha=flcfg.dirichlet_alpha,
                           samples_per_client=samples_per_client,
                           ref_samples=flcfg.ref_samples, seed=seed)


def _resolve_scenario(scenario: ScenarioLike) -> Optional[Scenario]:
    return get_scenario(scenario) if isinstance(scenario, str) else scenario


def run_simulation(flcfg: FLConfig, *, method: Optional[str] = None,
                   scenario: ScenarioLike = None,
                   dataset: str = "cifar10", rounds: Optional[int] = None,
                   eval_every: int = 5, seed: int = 0,
                   data: Optional[FederatedData] = None,
                   engine: str = "auto",
                   verbose: bool = False) -> SimResult:
    """Run one (method, scenario) simulation.

    ``scenario`` — a ``repro.scenarios`` registry name or ``Scenario``:
    its FLConfig overrides are applied first (idempotent, so callers that
    already applied them can pass both) and its hooks ride along on the
    server. ``method`` defaults to ``flcfg.aggregator``; an explicit
    argument wins over the config field. ``engine`` is forwarded to
    ``FLServer`` (round-driver routing — see ``engine.resolve_engine``).
    """
    scenario = _resolve_scenario(scenario)
    if scenario is not None:
        flcfg = scenario.apply(flcfg)
    method = flcfg.aggregator if method is None else method
    rounds = rounds if rounds is not None else flcfg.rounds
    topo = make_topology(flcfg)
    data = data if data is not None else make_data(flcfg, dataset, seed)
    server = FLServer(flcfg, topo, data, method=method, seed=seed,
                      scenario=scenario, engine=engine)

    accs, ticks = [], []
    for t in range(rounds):
        server.run_round(t)
        if (t + 1) % eval_every == 0 or t == rounds - 1:
            acc = server.evaluate()
            accs.append(acc)
            ticks.append(t + 1)
            if verbose:
                print(f"[{method}/{flcfg.attack}] round {t+1:4d} "
                      f"acc={acc:.4f} cum_cost=${server.cum_cost:.4f}")
    # rounds=0 yields no evals -> final_accuracy None. FLServer always
    # carries rep today; the getattr keeps SimResult construction working
    # for server implementations without reputation state.
    rep = getattr(server, "rep", None)
    return SimResult(method=method, attack=flcfg.attack, accuracy=accs,
                     rounds=ticks,
                     final_accuracy=accs[-1] if accs else None,
                     total_cost=server.cum_cost,
                     reputation=(np.array(rep.ema) if rep is not None
                                 else None),
                     malicious=server.malicious,
                     intra_bytes=server.cum_intra_bytes,
                     cross_bytes=server.cum_cross_bytes,
                     scenario=scenario.name if scenario is not None else None)


def run_simulation_batch(flcfg: FLConfig, *, seeds: Sequence[int],
                         method: Optional[str] = None,
                         scenario: ScenarioLike = None,
                         dataset: str = "cifar10",
                         rounds: Optional[int] = None,
                         data: Optional[FederatedData] = None
                         ) -> List[SimResult]:
    """Device-resident multi-seed sweep: ``lax.scan`` over rounds,
    ``vmap`` over seeds — the whole grid cell is one jitted device call.

    Semantics match ``run_simulation`` driven by the engine-backed
    ``FLServer`` (a single-seed batch is bit-identical to the host loop;
    see tests/test_determinism.py), except that accuracy is evaluated
    once, after the final round. Each seed gets its own data partition,
    model init and adversary draw unless a shared ``data`` is passed.
    Requires a jittable (method, attack, scenario) combination — host-
    hook scenarios raise (run them through ``run_simulation``).
    """
    scenario = _resolve_scenario(scenario)
    if scenario is not None:
        flcfg = scenario.apply(flcfg)
    method = flcfg.aggregator if method is None else method
    rounds = rounds if rounds is not None else flcfg.rounds
    topo = make_topology(flcfg)
    datas = [data if data is not None else make_data(flcfg, dataset, s)
             for s in seeds]
    static = engine_mod.static_from(
        flcfg, topo, method, scenario,
        input_shape=datas[0].client_x.shape[2:],
        n_classes=datas[0].n_classes)
    eng = engine_mod.compiled(static)
    if data is not None:
        # stage the shared sample arrays on device ONCE; only labels
        # (poisoning) and the adversary draw differ per seed
        sx, rx, ry = (jnp.asarray(data.client_x), jnp.asarray(data.ref_x),
                      jnp.asarray(data.ref_y))
        mals = [engine_mod.draw_malicious(flcfg, topo.n_clients, s)
                for s in seeds]
        dev = [engine_mod.ClientData(
                   client_x=sx,
                   client_y=jnp.asarray(
                       engine_mod.poison_labels(flcfg, data, m, s)),
                   ref_x=rx, ref_y=ry, malicious=jnp.asarray(m))
               for m, s in zip(mals, seeds)]
    else:
        dev = [engine_mod.make_client_data(flcfg, topo, d, s)
               for d, s in zip(datas, seeds)]
    states = [eng.init_state(s) for s in seeds]

    stack = lambda *xs: np.stack([np.asarray(x) for x in xs])
    if rounds == 0:
        finals, delivered, reps = states, None, None
    elif len(seeds) == 1:
        # unbatched scan: bit-identical to the per-round engine driver
        fin, outs = eng.run(states[0], dev[0], rounds)
        finals = [fin]
        delivered = np.asarray(outs.delivered)[None]       # (1, T, N)
        reps = np.asarray(outs.rep)[None]
    elif data is not None:
        # one dataset shared across seeds: broadcast the sample arrays
        # (one device copy) and stack only the per-seed leaves (poisoned
        # labels + adversary draw)
        shared = engine_mod.ClientData(
            client_x=dev[0].client_x,
            client_y=stack(*[d.client_y for d in dev]),
            ref_x=dev[0].ref_x, ref_y=dev[0].ref_y,
            malicious=stack(*[d.malicious for d in dev]))
        fin, outs = eng.run_batch_shared(jax.tree.map(stack, *states),
                                         shared, rounds)
        finals = [jax.tree.map(lambda x, i=i: x[i], fin)
                  for i in range(len(seeds))]
        delivered = np.asarray(outs.delivered)             # (S, T, N)
        reps = np.asarray(outs.rep)
    else:
        fin, outs = eng.run_batch(jax.tree.map(stack, *states),
                                  jax.tree.map(stack, *dev), rounds)
        finals = [jax.tree.map(lambda x, i=i: x[i], fin)
                  for i in range(len(seeds))]
        delivered = np.asarray(outs.delivered)             # (S, T, N)
        reps = np.asarray(outs.rep)

    results = []
    for i, s in enumerate(seeds):
        fin = finals[i]
        if rounds == 0:
            acc, ticks, cost, ib, cb = [], [], 0.0, 0.0, 0.0
            rep = np.array(fin.rep_ema)
        else:
            a = client_mod.accuracy(fin.params,
                                    jnp.asarray(datas[i].test_x),
                                    jnp.asarray(datas[i].test_y))
            acc, ticks = [a], [rounds]
            # byte-exact float64 accounting from the delivered masks —
            # the same reduction the per-round FLServer driver performs
            rows = eng.host_round_accounting(delivered[i])
            cost, ib, cb = (float(rows[:, 0].sum()),
                            float(rows[:, 1].sum()),
                            float(rows[:, 2].sum()))
            rep = reps[i, -1]
        results.append(SimResult(
            method=method, attack=flcfg.attack, accuracy=acc, rounds=ticks,
            final_accuracy=acc[-1] if acc else None, total_cost=cost,
            reputation=np.array(rep),
            malicious=np.asarray(dev[i].malicious),
            intra_bytes=ib, cross_bytes=cb,
            scenario=scenario.name if scenario is not None else None))
    return results


def run_simulation_sharded(flcfg: FLConfig, *,
                           method: Optional[str] = None,
                           scenario: ScenarioLike = None,
                           dataset: str = "cifar10",
                           rounds: Optional[int] = None, seed: int = 0,
                           data: Optional[FederatedData] = None,
                           n_devices: Optional[int] = None) -> SimResult:
    """One simulation on the mesh-sharded engine
    (``repro.federated.sharded``): clients laid out over a
    ``("cloud", "client")`` device mesh, Eq. 5–13 as a two-stage
    intra-cloud/cross-cloud reduction, the whole run ONE ``shard_map``'d
    ``lax.scan`` call.

    Semantics match ``run_simulation`` on the scan engine to documented
    fp tolerance (exactly for selection/delivery masks and byte/cost
    accounting; ~1e-4 relative for params/reputation, the bound
    tests/test_sharded.py enforces). Accuracy is evaluated once, after
    the final round. Raises with a clear reason for configurations the sharded
    engine refuses (matrix-shaped attacks/codecs, host-hook scenarios,
    populations that do not tile the device count).
    """
    from repro.federated import sharded as sharded_mod

    scenario = _resolve_scenario(scenario)
    if scenario is not None:
        flcfg = scenario.apply(flcfg)
    method = flcfg.aggregator if method is None else method
    rounds = rounds if rounds is not None else flcfg.rounds
    topo = make_topology(flcfg)
    data = data if data is not None else make_data(flcfg, dataset, seed)
    eng = sharded_mod.engine_for(flcfg, topo, data, method, scenario,
                                 n_devices=n_devices)
    malicious = engine_mod.draw_malicious(flcfg, topo.n_clients, seed)
    dev = eng.stage_data(engine_mod.make_client_data(
        flcfg, topo, data, seed, malicious=malicious))
    state = eng.init_state(seed)

    if rounds == 0:
        return SimResult(method=method, attack=flcfg.attack, accuracy=[],
                         rounds=[], final_accuracy=None, total_cost=0.0,
                         reputation=np.array(state.rep_ema),
                         malicious=malicious,
                         scenario=(scenario.name if scenario is not None
                                   else None))

    fin, outs = eng.run(state, dev, rounds)
    acc = client_mod.accuracy(fin.params, jnp.asarray(data.test_x),
                              jnp.asarray(data.test_y))
    # byte-exact float64 accounting from the delivered masks — the same
    # reduction every other engine driver performs
    rows = eng.host_round_accounting(np.asarray(outs.delivered))
    return SimResult(
        method=method, attack=flcfg.attack, accuracy=[acc], rounds=[rounds],
        final_accuracy=acc, total_cost=float(rows[:, 0].sum()),
        reputation=np.array(fin.rep_ema), malicious=malicious,
        intra_bytes=float(rows[:, 1].sum()),
        cross_bytes=float(rows[:, 2].sum()),
        scenario=scenario.name if scenario is not None else None)


def compare_methods(flcfg: FLConfig, methods: List[str], *,
                    scenario: ScenarioLike = None,
                    dataset: str = "cifar10", rounds: int = 30,
                    seed: int = 0, verbose: bool = False
                    ) -> Dict[str, SimResult]:
    """Run every method on ONE dataset/scenario so comparisons are
    apples-to-apples (shared data partition, shared scenario hooks)."""
    scenario = _resolve_scenario(scenario)
    if scenario is not None:
        flcfg = scenario.apply(flcfg)   # before make_data: overrides may
    data = make_data(flcfg, dataset, seed)  # change topology/partition
    return {m: run_simulation(flcfg, method=m, scenario=scenario,
                              dataset=dataset, rounds=rounds, seed=seed,
                              data=data, verbose=verbose)
            for m in methods}

"""End-to-end simulation harness reproducing the paper's experimental
protocol (3 clouds x 30 clients, Dirichlet non-IID, 4 attacks,
6 methods)."""
from __future__ import annotations

import time
from dataclasses import dataclass, fields, replace
from typing import Any, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FLConfig
from repro.core.fl_types import CloudTopology
from repro.data.pipeline import FederatedData, build_federated
from repro.data.synthetic import make_cifar10_like, make_femnist_like
from repro.federated import client as client_mod
from repro.federated import engine as engine_mod
from repro.federated.server import FLServer
from repro.scenarios import Scenario, get_scenario
from repro.telemetry import spans
from repro.telemetry import taps as taps_mod
from repro.telemetry.schema import RunContext
from repro.telemetry.taps import TapSpec

ScenarioLike = Union[str, Scenario, None]


@dataclass
class SimResult:
    method: str
    attack: str
    accuracy: List[float]
    rounds: List[int]
    final_accuracy: Optional[float]   # None when no eval ran (rounds=0)
    total_cost: float
    reputation: Optional[np.ndarray] = None
    malicious: Optional[np.ndarray] = None
    intra_bytes: float = 0.0          # cumulative wire bytes, intra-class
    cross_bytes: float = 0.0          # cumulative wire bytes, cross-cloud
    scenario: Optional[str] = None    # registry name when one was run


def make_topology(flcfg: FLConfig) -> CloudTopology:
    return CloudTopology.even(flcfg.n_clouds, flcfg.clients_per_cloud)


def make_data(flcfg: FLConfig, dataset: str = "cifar10", seed: int = 0,
              n_samples: int = 12000, samples_per_client: int = 96
              ) -> FederatedData:
    topo = make_topology(flcfg)
    ds = (make_cifar10_like(n_samples, seed) if dataset == "cifar10"
          else make_femnist_like(n_samples, seed))
    return build_federated(ds, topo, alpha=flcfg.dirichlet_alpha,
                           samples_per_client=samples_per_client,
                           ref_samples=flcfg.ref_samples, seed=seed)


def _resolve_scenario(scenario: ScenarioLike) -> Optional[Scenario]:
    return get_scenario(scenario) if isinstance(scenario, str) else scenario


def _engine_context(telemetry: Any, *, engine_name: str, eng, flcfg: FLConfig,
                    topo: CloudTopology, method: str,
                    scenario: Optional[Scenario], seed: int,
                    malicious: np.ndarray, rounds: int) -> RunContext:
    """RunContext for a device-engine driver (scan or sharded), with
    ``run_start`` already emitted — one construction path so the batch,
    sharded and streaming drivers describe runs identically."""
    st = eng.static
    ctx = RunContext(
        telemetry, engine=engine_name, run_id=f"{method}-s{seed}",
        method=method, attack=flcfg.attack, seed=seed, topo=topo,
        d_params=eng.d_params, hierarchical=st.hierarchical,
        m_selected=engine_mod.selected_total(st), malicious=malicious,
        client_payload=eng.client_payload, edge_payload=eng.edge_payload,
        c_intra=st.c_intra, c_cross=st.c_cross,
        price_multipliers=st.price_multipliers,
        malice_warmup=st.malice_warmup,
        scenario=scenario.name if scenario is not None else None,
        trust_features=flcfg.trust_features)
    ctx.run_start(rounds=rounds,
                  config={f.name: getattr(flcfg, f.name)
                          for f in fields(flcfg)})
    return ctx


def _replay_rounds(ctx: RunContext, delivered: np.ndarray,
                   reps: np.ndarray, params_l2: np.ndarray,
                   feat_weights: Optional[np.ndarray] = None) -> None:
    """Emit round events from stacked (T, ...) RoundOut arrays — the
    post-run path for drivers that cannot stream (vmapped batches, the
    sharded engine whose per-shard callbacks would duplicate events)."""
    for t in range(len(delivered)):
        ctx.round(t, delivered[t], reps[t], float(params_l2[t]),
                  feat_weights=(feat_weights[t] if feat_weights is not None
                                else None))


def run_simulation(flcfg: FLConfig, *, method: Optional[str] = None,
                   scenario: ScenarioLike = None,
                   dataset: str = "cifar10", rounds: Optional[int] = None,
                   eval_every: int = 5, seed: int = 0,
                   data: Optional[FederatedData] = None,
                   engine: str = "auto",
                   telemetry: Any = None,
                   verbose: bool = False) -> SimResult:
    """Run one (method, scenario) simulation.

    ``scenario`` — a ``repro.scenarios`` registry name or ``Scenario``:
    its FLConfig overrides are applied first (idempotent, so callers that
    already applied them can pass both) and its hooks ride along on the
    server. ``method`` defaults to ``flcfg.aggregator``; an explicit
    argument wins over the config field. ``engine`` is forwarded to
    ``FLServer`` (round-driver routing — see ``engine.resolve_engine``).
    ``telemetry`` — an optional ``repro.telemetry.Telemetry`` recorder:
    the server emits run_start / per-round / span events, this harness
    adds eval events and the closing run_end.
    """
    scenario = _resolve_scenario(scenario)
    if scenario is not None:
        flcfg = scenario.apply(flcfg)
    method = flcfg.aggregator if method is None else method
    rounds = rounds if rounds is not None else flcfg.rounds
    topo = make_topology(flcfg)
    data = data if data is not None else make_data(flcfg, dataset, seed)
    server = FLServer(flcfg, topo, data, method=method, seed=seed,
                      scenario=scenario, engine=engine,
                      telemetry=telemetry)

    accs, ticks = [], []
    for t in range(rounds):
        server.run_round(t)
        if (t + 1) % eval_every == 0 or t == rounds - 1:
            acc = server.evaluate()
            accs.append(acc)
            ticks.append(t + 1)
            server.record_eval(t, acc)
            if verbose:
                print(f"[{method}/{flcfg.attack}] round {t+1:4d} "
                      f"acc={acc:.4f} cum_cost=${server.cum_cost:.4f}")
    server.finish_telemetry()
    # rounds=0 yields no evals -> final_accuracy None. FLServer always
    # carries rep today; the getattr keeps SimResult construction working
    # for server implementations without reputation state.
    rep = getattr(server, "rep", None)
    return SimResult(method=method, attack=flcfg.attack, accuracy=accs,
                     rounds=ticks,
                     final_accuracy=accs[-1] if accs else None,
                     total_cost=server.cum_cost,
                     reputation=(np.array(rep.ema) if rep is not None
                                 else None),
                     malicious=server.malicious,
                     intra_bytes=server.cum_intra_bytes,
                     cross_bytes=server.cum_cross_bytes,
                     scenario=scenario.name if scenario is not None else None)


def run_simulation_batch(flcfg: FLConfig, *, seeds: Sequence[int],
                         method: Optional[str] = None,
                         scenario: ScenarioLike = None,
                         dataset: str = "cifar10",
                         rounds: Optional[int] = None,
                         data: Optional[FederatedData] = None,
                         telemetry: Any = None) -> List[SimResult]:
    """Device-resident multi-seed sweep: ``lax.scan`` over rounds,
    ``vmap`` over seeds — the whole grid cell is one jitted device call.

    Semantics match ``run_simulation`` driven by the engine-backed
    ``FLServer`` (a single-seed batch is bit-identical to the host loop;
    see tests/test_determinism.py), except that accuracy is evaluated
    once, after the final round. Each seed gets its own data partition,
    model init and adversary draw unless a shared ``data`` is passed.
    Requires a jittable (method, attack, scenario) combination — host-
    hook scenarios raise (run them through ``run_simulation``).

    ``telemetry``: a single-seed batch streams its round events LIVE out
    of the running scan (ordered ``jax.debug.callback`` tap — and those
    events are byte-identical to the per-round ``FLServer`` driver's);
    multi-seed batches run untapped (ordered callbacks cannot cross
    vmap) and replay per-seed events from the stacked outputs after the
    device call.
    """
    scenario = _resolve_scenario(scenario)
    if scenario is not None:
        flcfg = scenario.apply(flcfg)
    method = flcfg.aggregator if method is None else method
    rounds = rounds if rounds is not None else flcfg.rounds
    topo = make_topology(flcfg)
    datas = [data if data is not None else make_data(flcfg, dataset, s)
             for s in seeds]
    static = engine_mod.static_from(
        flcfg, topo, method, scenario,
        input_shape=datas[0].client_x.shape[2:],
        n_classes=datas[0].n_classes)
    eng = engine_mod.compiled(static)
    if data is not None:
        # stage the shared sample arrays on device ONCE; only labels
        # (poisoning) and the adversary draw differ per seed
        sx, rx, ry = (jnp.asarray(data.client_x), jnp.asarray(data.ref_x),
                      jnp.asarray(data.ref_y))
        mals = [engine_mod.draw_malicious(flcfg, topo.n_clients, s)
                for s in seeds]
        dev = [engine_mod.ClientData(
                   client_x=sx,
                   client_y=jnp.asarray(
                       engine_mod.poison_labels(flcfg, data, m, s)),
                   ref_x=rx, ref_y=ry, malicious=jnp.asarray(m))
               for m, s in zip(mals, seeds)]
    else:
        dev = [engine_mod.make_client_data(flcfg, topo, d, s)
               for d, s in zip(datas, seeds)]
    states = [eng.init_state(s) for s in seeds]
    ctxs = None
    if telemetry is not None:
        ctxs = [_engine_context(telemetry, engine_name="jit", eng=eng,
                                flcfg=flcfg, topo=topo, method=method,
                                scenario=scenario, seed=s,
                                malicious=np.asarray(dev[i].malicious),
                                rounds=rounds)
                for i, s in enumerate(seeds)]
    streamed = False

    stack = lambda *xs: np.stack([np.asarray(x) for x in xs])
    t0 = time.perf_counter()
    if rounds == 0:
        finals, delivered, reps, pl2, fw = states, None, None, None, None
    elif len(seeds) == 1:
        # unbatched scan: bit-identical to the per-round engine driver
        if ctxs is not None:
            # live stream: compile the tapped executable and install the
            # collector for the duration of the device call — collecting()
            # drains the async callback queue before uninstalling
            ctx = ctxs[0]
            tapped = engine_mod.compiled(static, TapSpec(enabled=True))
            collect = lambda t, out: ctx.round(
                int(t), np.asarray(out.delivered), np.asarray(out.rep),
                float(out.params_l2),
                feat_weights=(np.asarray(out.feat_weights)
                              if np.asarray(out.feat_weights).size
                              else None))
            with taps_mod.collecting(collect):
                fin, outs = tapped.run(states[0], dev[0], rounds)
                jax.block_until_ready(outs.delivered)
            streamed = True
        else:
            fin, outs = eng.run(states[0], dev[0], rounds)
        finals = [fin]
        delivered = np.asarray(outs.delivered)[None]       # (1, T, N)
        reps = np.asarray(outs.rep)[None]
        pl2 = np.asarray(outs.params_l2)[None]
        fw = np.asarray(outs.feat_weights)[None]           # (1, T, F|0)
    elif data is not None:
        # one dataset shared across seeds: broadcast the sample arrays
        # (one device copy) and stack only the per-seed leaves (poisoned
        # labels + adversary draw)
        shared = engine_mod.ClientData(
            client_x=dev[0].client_x,
            client_y=stack(*[d.client_y for d in dev]),
            ref_x=dev[0].ref_x, ref_y=dev[0].ref_y,
            malicious=stack(*[d.malicious for d in dev]))
        fin, outs = eng.run_batch_shared(jax.tree.map(stack, *states),
                                         shared, rounds)
        finals = [jax.tree.map(lambda x, i=i: x[i], fin)
                  for i in range(len(seeds))]
        delivered = np.asarray(outs.delivered)             # (S, T, N)
        reps = np.asarray(outs.rep)
        pl2 = np.asarray(outs.params_l2)
        fw = np.asarray(outs.feat_weights)                 # (S, T, F|0)
    else:
        fin, outs = eng.run_batch(jax.tree.map(stack, *states),
                                  jax.tree.map(stack, *dev), rounds)
        finals = [jax.tree.map(lambda x, i=i: x[i], fin)
                  for i in range(len(seeds))]
        delivered = np.asarray(outs.delivered)             # (S, T, N)
        reps = np.asarray(outs.rep)
        pl2 = np.asarray(outs.params_l2)
        fw = np.asarray(outs.feat_weights)                 # (S, T, F|0)
    if ctxs is not None:
        dt = time.perf_counter() - t0
        for ctx in ctxs:
            ctx.span("engine.run", dt, phase="compile+execute")

    results = []
    for i, s in enumerate(seeds):
        fin = finals[i]
        if rounds == 0:
            acc, ticks, cost, ib, cb = [], [], 0.0, 0.0, 0.0
            rep = np.array(fin.rep_ema)
        else:
            a = client_mod.accuracy(fin.params,
                                    jnp.asarray(datas[i].test_x),
                                    jnp.asarray(datas[i].test_y))
            acc, ticks = [a], [rounds]
            # byte-exact float64 accounting from the delivered masks —
            # the same reduction the per-round FLServer driver performs
            rows = eng.host_round_accounting(delivered[i])
            cost, ib, cb = (float(rows[:, 0].sum()),
                            float(rows[:, 1].sum()),
                            float(rows[:, 2].sum()))
            rep = reps[i, -1]
        if ctxs is not None:
            ctx = ctxs[i]
            if rounds > 0 and not streamed:
                _replay_rounds(ctx, delivered[i], reps[i], pl2[i],
                               fw[i] if fw is not None and fw.shape[-1]
                               else None)
            if acc:
                ctx.eval(rounds - 1, float(acc[0]))
            ctx.run_end()
        results.append(SimResult(
            method=method, attack=flcfg.attack, accuracy=acc, rounds=ticks,
            final_accuracy=acc[-1] if acc else None, total_cost=cost,
            reputation=np.array(rep),
            malicious=np.asarray(dev[i].malicious),
            intra_bytes=ib, cross_bytes=cb,
            scenario=scenario.name if scenario is not None else None))
    return results


def run_simulation_sharded(flcfg: FLConfig, *,
                           method: Optional[str] = None,
                           scenario: ScenarioLike = None,
                           dataset: str = "cifar10",
                           rounds: Optional[int] = None, seed: int = 0,
                           data: Optional[FederatedData] = None,
                           n_devices: Optional[int] = None,
                           telemetry: Any = None) -> SimResult:
    """One simulation on the mesh-sharded engine
    (``repro.federated.sharded``): clients laid out over a
    ``("cloud", "client")`` device mesh, Eq. 5–13 as a two-stage
    intra-cloud/cross-cloud reduction, the whole run ONE ``shard_map``'d
    ``lax.scan`` call.

    Semantics match ``run_simulation`` on the scan engine to documented
    fp tolerance (exactly for selection/delivery masks and byte/cost
    accounting; ~1e-4 relative for params/reputation, the bound
    tests/test_sharded.py enforces). Accuracy is evaluated once, after
    the final round. Raises with a clear reason for configurations the sharded
    engine refuses (matrix-shaped attacks/codecs, host-hook scenarios,
    populations that do not tile the device count).
    """
    from repro.federated import sharded as sharded_mod

    scenario = _resolve_scenario(scenario)
    if scenario is not None:
        flcfg = scenario.apply(flcfg)
    method = flcfg.aggregator if method is None else method
    rounds = rounds if rounds is not None else flcfg.rounds
    topo = make_topology(flcfg)
    data = data if data is not None else make_data(flcfg, dataset, seed)
    eng = sharded_mod.engine_for(flcfg, topo, data, method, scenario,
                                 n_devices=n_devices)
    malicious = engine_mod.draw_malicious(flcfg, topo.n_clients, seed)
    dev = eng.stage_data(engine_mod.make_client_data(
        flcfg, topo, data, seed, malicious=malicious))
    state = eng.init_state(seed)
    ctx = (None if telemetry is None else
           _engine_context(telemetry, engine_name="shard", eng=eng,
                           flcfg=flcfg, topo=topo, method=method,
                           scenario=scenario, seed=seed,
                           malicious=np.asarray(malicious), rounds=rounds))

    if rounds == 0:
        if ctx is not None:
            ctx.run_end()
        return SimResult(method=method, attack=flcfg.attack, accuracy=[],
                         rounds=[], final_accuracy=None, total_cost=0.0,
                         reputation=np.array(state.rep_ema),
                         malicious=malicious,
                         scenario=(scenario.name if scenario is not None
                                   else None))

    t0 = time.perf_counter()
    fin, outs = eng.run(state, dev, rounds)
    acc = client_mod.accuracy(fin.params, jnp.asarray(data.test_x),
                              jnp.asarray(data.test_y))
    if ctx is not None:
        # per-shard callbacks would emit one event per device; replay the
        # stacked RoundOut instead (digests match scan to ~1e-4)
        ctx.span("engine.run", time.perf_counter() - t0,
                 phase="compile+execute")
        sh_fw = np.asarray(outs.feat_weights)
        _replay_rounds(ctx, np.asarray(outs.delivered),
                       np.asarray(outs.rep), np.asarray(outs.params_l2),
                       sh_fw if sh_fw.shape[-1] else None)
        ctx.eval(rounds - 1, float(acc))
        ctx.run_end()
    # byte-exact float64 accounting from the delivered masks — the same
    # reduction every other engine driver performs
    rows = eng.host_round_accounting(np.asarray(outs.delivered))
    return SimResult(
        method=method, attack=flcfg.attack, accuracy=[acc], rounds=[rounds],
        final_accuracy=acc, total_cost=float(rows[:, 0].sum()),
        reputation=np.array(fin.rep_ema), malicious=malicious,
        intra_bytes=float(rows[:, 1].sum()),
        cross_bytes=float(rows[:, 2].sum()),
        scenario=scenario.name if scenario is not None else None)


def compare_methods(flcfg: FLConfig, methods: List[str], *,
                    scenario: ScenarioLike = None,
                    dataset: str = "cifar10", rounds: int = 30,
                    seed: int = 0, verbose: bool = False
                    ) -> Dict[str, SimResult]:
    """Run every method on ONE dataset/scenario so comparisons are
    apples-to-apples (shared data partition, shared scenario hooks)."""
    scenario = _resolve_scenario(scenario)
    if scenario is not None:
        flcfg = scenario.apply(flcfg)   # before make_data: overrides may
    data = make_data(flcfg, dataset, seed)  # change topology/partition
    return {m: run_simulation(flcfg, method=m, scenario=scenario,
                              dataset=dataset, rounds=rounds, seed=seed,
                              data=data, verbose=verbose)
            for m in methods}

"""Device-resident round engine: the full Cost-TrustFL round as a pure
``round_step(state, t) -> (state, metrics)`` function, driven by
``lax.scan`` over rounds and ``vmap`` over seeds.

The host loop (``FLServer.run_round``) pays Python dispatch, numpy RNG
and host↔device syncs ~10 times per round; at simulation scale that
overhead dominates the actual math. Here the whole pipeline — Eq. 10
selection (with the per-cloud quota and tie-break noise), vmapped local
training over a fixed-size selected set, update-level attacks, per-link
compression with error-feedback residuals carried in state, hierarchical
aggregation, and byte/cost accounting — lives inside one jitted program,
so a T-round simulation is ONE device call and an S-seed sweep is one
vmapped device call.

Design rules that keep everything jit/scan/vmap-compatible:

* every shape is static: the selected set always has
  :func:`repro.core.selection.selected_count` rows (dropout masks rows
  instead of shrinking them);
* environment scenarios enter as *data* (``scenarios.JitHooks``): a
  dropout probability, an active-malice warmup round, a per-round
  ``c_cross`` multiplier schedule indexed by ``t``;
* all round randomness derives from ``PRNGKey(seed·7919 + t)`` — the
  same key schedule as the host loop, so a resumed/re-driven round
  replays bit-identically (the product is computed in int32 on device,
  so seeds ≥ ~271k wrap mod 2³² — still fully deterministic, just no
  longer the literal formula);
* compiled engines are cached per :class:`EngineStatic`, so the dozens
  of servers a scenario × method matrix instantiates share executables.

``FLServer`` is a thin stateful wrapper over :func:`compiled`;
``run_simulation_batch`` drives the vmapped path. Scenarios with host
hooks but no ``jit_hooks`` fall back to the legacy host loop.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import build_link_policy, ef_step_masked
from repro.configs.base import FLConfig
from repro.core import (CloudTopology, CostModel, ReputationState,
                        apply_update_attack, coordinate_median, fedavg,
                        fltrust, krum, trimmed_mean)
from repro.core.attacks import UPDATE_ATTACKS
from repro.core import features as feats_mod
from repro.core.shapley import gradient_contribution
from repro.core.trust import cloud_trust
from repro.core.cost import hierarchical_unit_costs_jax, round_bytes_jax
from repro.core.selection import (exploration_quota,
                                  select_clients_jax, selected_count)
from repro.data.pipeline import FederatedData
from repro.federated import client as client_mod
from repro.scenarios.base import JitHooks, Scenario
from repro.telemetry import taps as taps_mod
from repro.telemetry.taps import TapSpec

Array = jax.Array

_GB = 1024.0 ** 3
REF_BATCH = 32          # reference LocalTrain batch (client default)

# key-fold tags for the per-round sub-streams. 0–3 and 211/223 are the
# compression folds inherited from the host loop; selection and dropout
# are engine-only streams (the host path draws those from numpy).
_FOLD_SELECT = 131
_FOLD_DROPOUT = 137
_FOLD_CLIENT_WIRE = 211
_FOLD_EDGE_WIRE = 223

# aggregators whose math is a 0-weighted sum over masked rows, i.e. safe
# when dropout zeroes non-delivered rows of the fixed-size update matrix.
# Order statistics (krum / trimmed_mean / median) would see the zero rows
# as extra clients — those fall back to the host loop under dropout.
MASKED_DELIVERY_OK = ("cost_trustfl", "fedavg", "fltrust")

METHODS = ("cost_trustfl", "fedavg", "krum", "trimmed_mean", "median",
           "fltrust")


# ---------------------------------------------------------------------------
# pytrees

class RoundState(NamedTuple):
    """Everything a round mutates, as one device-resident pytree
    (vmappable over a leading seeds axis)."""
    params: Dict[str, Array]     # model parameters
    rep_ema: Array               # (N,) Eq. 9 reputation EMA
    res_client: Array            # (N, D) EF residuals, client uplinks ((0,) when inactive)
    res_edge: Array              # (K, D) EF residuals, edge uplinks ((0,) when inactive)
    cum_cost: Array              # () running $ (float32; host reduces f64)
    cum_intra_bytes: Array       # () running intra-class wire bytes
    cum_cross_bytes: Array       # () running cross-cloud wire bytes
    feat_sep: Array              # (F,) per-feature separability EMA
                                 # (trust_features="multi"; (0,) otherwise)
    seed: Array                  # () int32 PRNG root: round key = PRNGKey(seed·7919+t)


class RoundOut(NamedTuple):
    """Per-round metrics emitted by ``round_step`` (stacked to (T, ...)
    by the scan driver)."""
    delivered: Array             # (N,) bool — selected AND delivered
    rep: Array                   # (N,) post-update reputation EMA
    cost: Array                  # () $ this round (float32 mirror)
    intra_bytes: Array           # () wire bytes, intra-class
    cross_bytes: Array           # () wire bytes, cross-cloud
    params_l2: Array             # () L2 of the post-update params — the
                                 # RoundState digest telemetry fingerprints
    feat_weights: Array          # (F,) adaptive feature mixing weights
                                 # (trust_features="multi"; (0,) otherwise)


class ClientData(NamedTuple):
    """Per-seed, round-invariant device inputs."""
    client_x: Array              # (N, S, ...) per-client samples
    client_y: Array              # (N, S) labels (already poisoned)
    ref_x: Array                 # (K, R, ...) per-cloud reference sets
    ref_y: Array                 # (K, R)
    malicious: Array             # (N,) bool static adversary set


class LastLayerSpec(NamedTuple):
    """The paper's g^(L) slice, derived from the params template: the
    last two leaves by insertion order (weight + bias of the final FC
    layer for the CNN — but any model's tail, not a hardcoded name)."""
    names: Tuple[str, ...]       # leaf names, template insertion order
    flat_idx: np.ndarray         # their positions in the raveled vector


@dataclass(frozen=True)
class EngineStatic:
    """Hashable round-engine configuration — the ``lru_cache`` key for
    :func:`compiled`, so equal configs share one set of executables."""
    method: str
    cloud_of: Tuple[int, ...]
    n_clouds: int
    aggregator_cloud: int
    input_shape: Tuple[int, ...]
    n_classes: int
    clients_per_round: int
    cost_lambda: float
    c_intra: float
    c_cross: float
    attack: str
    attack_scale: float
    gaussian_sigma: float
    attack_z: float
    local_epochs: int
    local_batch: int
    lr: float
    server_lr: float
    ema_gamma: float
    malicious_frac: float
    compressor: str
    compress_ratio: float
    qsgd_levels: int
    link_policy: str
    p_drop: float
    malice_warmup: int
    price_multipliers: Tuple[float, ...]
    trust_features: str = "scalar"

    @property
    def hierarchical(self) -> bool:
        return self.method == "cost_trustfl"

    @property
    def multi_features(self) -> bool:
        """Multi-feature trust gating is a cost_trustfl refinement — the
        flat baselines have no Eq. 7 path for it to gate."""
        return self.hierarchical and self.trust_features == "multi"

    @property
    def n_clients(self) -> int:
        return len(self.cloud_of)

    def topology(self) -> CloudTopology:
        return CloudTopology(cloud_of=np.array(self.cloud_of),
                             n_clouds=self.n_clouds,
                             aggregator_cloud=self.aggregator_cloud)


# ---------------------------------------------------------------------------
# flat-vector plumbing

def tree_l2(tree) -> Array:
    """L2 norm over every leaf of a pytree (float32 scalar) — the cheap
    in-graph state digest both device engines emit per round (and the
    host loop mirrors via one tiny jitted reduce)."""
    return jnp.sqrt(sum(jnp.sum(jnp.square(l))
                        for l in jax.tree.leaves(tree)))


def ravel_rows(tree) -> Array:
    """Flatten a pytree with leading batch axis into (B, D), in
    ``ravel_pytree`` leaf order — one concat, no per-row unravel."""
    leaves = jax.tree.leaves(tree)
    b = leaves[0].shape[0]
    return jnp.concatenate([l.reshape(b, -1) for l in leaves], axis=1)


def unflatten_like(vec: Array, template) -> Any:
    """Inverse of a single-row :func:`ravel_rows`: split a (D,) vector
    back into the template's pytree (static slice bounds)."""
    leaves, treedef = jax.tree.flatten(template)
    out, off = [], 0
    for l in leaves:
        n = int(np.prod(l.shape)) if l.ndim else 1
        out.append(vec[off:off + n].reshape(l.shape))
        off += n
    return jax.tree.unflatten(treedef, out)


def last_layer_spec(params_template: Dict[str, Array]) -> LastLayerSpec:
    """Derive the trust path's last-layer slice from the template: the
    last two leaves by insertion order (for non-dict templates, the last
    two of ``jax.tree.leaves``), plus their static positions in the
    raveled vector so flat matrices can be sliced directly."""
    if isinstance(params_template, dict):
        names = tuple(list(params_template)[-2:])
        chosen = [params_template[n] for n in names]
    else:  # generic pytree: best effort over the leaf tail
        leaves = jax.tree.leaves(params_template)
        names = tuple(str(i) for i in range(len(leaves))[-2:])
        chosen = leaves[-2:]
    # ravel_pytree order == jax.tree.leaves order (dicts: sorted keys)
    leaves, _ = jax.tree.flatten(params_template)
    offsets, off = [], 0
    for l in leaves:
        offsets.append(off)
        off += int(np.prod(l.shape)) if l.ndim else 1
    pos = {id(l): o for l, o in zip(leaves, offsets)}
    idx = np.concatenate([
        np.arange(pos[id(c)], pos[id(c)] + int(np.prod(c.shape)))
        for c in chosen])
    return LastLayerSpec(names=names, flat_idx=idx)


# ---------------------------------------------------------------------------
# shared round primitives (scan engine + sharded engine)
#
# Selection and delivery are REPLICATED computations in the sharded
# engine (every shard evaluates them on the full (N,) reputation/key),
# so both engines must build them from the same closures — a fork here
# would silently break cross-engine parity the first time one side's
# draw order changed.

def round_key(seed, t) -> Array:
    """The engine key schedule: ``PRNGKey(seed·7919 + t)`` (int32 on
    device — same wrap-around caveat as the module docstring)."""
    return jax.random.PRNGKey(seed * 7919 + t)


def build_select_fn(st: "EngineStatic") -> Tuple[Callable, int]:
    """``(select(rep, c_cross_t, key) -> (N,) bool mask, m_total)`` for
    this config: jittable Eq. 10 with the per-cloud quota + tie-break
    noise for cost_trustfl, a uniform draw for the baselines."""
    topo = st.topology()
    n = topo.n_clients
    cloud_of_np = np.array(st.cloud_of)
    cloud_sizes = np.bincount(cloud_of_np, minlength=st.n_clouds)
    cloud_of_j = jnp.asarray(cloud_of_np)
    quota = exploration_quota(st.cost_lambda) if st.hierarchical else 0
    m_total = selected_count(n, st.clients_per_round, quota, cloud_of_np)

    def select(rep: Array, c_cross_t, key: Array) -> Array:
        if st.hierarchical:
            unit_costs = hierarchical_unit_costs_jax(
                cloud_of_j, cloud_sizes, st.aggregator_cloud, st.c_intra,
                c_cross_t)
            return select_clients_jax(
                rep, unit_costs, st.clients_per_round, st.cost_lambda,
                per_cloud_min=quota, cloud_of=cloud_of_np, key=key)
        perm = jax.random.permutation(key, n)
        return jnp.zeros((n,), bool).at[perm[:m_total]].set(True)

    return select, m_total


def selected_total(st: "EngineStatic") -> int:
    """Static population of the selected set for this config — the
    ``n_selected`` every telemetry round event reports (see
    ``core.selection.selected_count``)."""
    quota = exploration_quota(st.cost_lambda) if st.hierarchical else 0
    return selected_count(st.n_clients, st.clients_per_round, quota,
                          np.array(st.cloud_of))


def build_deliver_fn(st: "EngineStatic") -> Callable:
    """``deliver(sel, key) -> (N,) bool`` dropout mask (identity when the
    scenario declares no ``p_drop``; never drops the whole round)."""
    n = st.n_clients

    def deliver(sel: Array, key: Array) -> Array:
        if st.p_drop <= 0.0:
            return sel
        out = sel & (jax.random.uniform(key, (n,)) >= st.p_drop)
        # never drop everyone: re-admit the first selected client
        need = sel.any() & ~out.any()
        return out | (need & (jnp.arange(n) == jnp.argmax(sel)) & sel)

    return deliver


def build_edge_wire_fn(lp, k: int, aggregator_cloud: int) -> Callable:
    """``edge_wire(cloud_aggs, res_edge, active, ekey) -> (cloud_aggs,
    res_edge)``: the edge→global wire model shared by every driver (scan
    engine, sharded engine, and the host loop's ``cloud_transform``) —
    round-trips the (K, D) cloud aggregates through each cloud's uplink
    codec (intra-class for the aggregator's own cloud, cross for the
    rest) with error feedback on the edge residuals.

    ``active`` is a (K, 1) mask of clouds with ≥1 delivered client:
    inactive clouds pass through and keep their residual — their row is
    the receiver-side reference fallback, nothing crossed the wire.
    ``ekey`` is the ``_FOLD_EDGE_WIRE`` stream; the 2=intra / 3=cross
    sub-folds are part of the cross-engine parity contract — change
    them here or nowhere."""
    def edge_wire(cloud_aggs: Array, res_edge: Array, active: Array,
                  ekey: Array) -> Tuple[Array, Array]:
        is_agg = (jnp.arange(k) == aggregator_cloud)[:, None]
        y = cloud_aggs + res_edge
        hat_cross = lp.cross.roundtrip(y, jax.random.fold_in(ekey, 3))
        # identity roundtrips are free; "all" shares one codec object,
        # so don't run it twice over the same rows
        hat_intra = (hat_cross if lp.intra is lp.cross
                     else lp.intra.roundtrip(y, jax.random.fold_in(ekey, 2)))
        x_hat = jnp.where(is_agg, hat_intra, hat_cross)
        return (jnp.where(active, x_hat, cloud_aggs),
                jnp.where(active, y - x_hat, res_edge))

    return edge_wire


def init_round_state(st: "EngineStatic", d: int, seed: int, *,
                     client_wire_active: bool,
                     edge_wire_active: bool) -> RoundState:
    """The round-zero :class:`RoundState` shared by the scan and sharded
    engines (the sharded engine adds mesh placement on top): per-seed
    model init, uniform reputation, EF residual buffers only for the
    link classes whose codecs actually distort the wire."""
    n, k = st.n_clients, st.n_clouds
    params = client_mod.cnn_init(jax.random.PRNGKey(seed), st.input_shape,
                                 st.n_classes)
    return RoundState(
        params=params,
        rep_ema=ReputationState.init(n).ema,
        res_client=(jnp.zeros((n, d), jnp.float32)
                    if client_wire_active else jnp.zeros((0,))),
        res_edge=(jnp.zeros((k, d), jnp.float32)
                  if edge_wire_active else jnp.zeros((0,))),
        cum_cost=jnp.float32(0.0), cum_intra_bytes=jnp.float32(0.0),
        cum_cross_bytes=jnp.float32(0.0),
        feat_sep=(jnp.zeros((feats_mod.N_FEATURES,), jnp.float32)
                  if st.multi_features else jnp.zeros((0,))),
        seed=jnp.int32(seed))


def host_round_accounting(static: "EngineStatic", d_params: int,
                          client_payload: np.ndarray,
                          edge_payload: np.ndarray,
                          delivered_rounds: np.ndarray,
                          t0: int = 0) -> np.ndarray:
    """Byte-exact float64 (cost, intra_bytes, cross_bytes) rows for a
    (T, N) stack of delivered masks — the single accounting code path
    shared by every engine driver (per-round ``FLServer``, the
    ``lax.scan`` batch, and the sharded mesh engine), so all of them
    bill identically at any scale, immune to the float32 in-state
    mirrors' 2^24 exactness bound."""
    st = static
    topo = st.topology()
    mults = st.price_multipliers
    rows = np.empty((len(delivered_rounds), 3), np.float64)
    for i, dmask in enumerate(np.asarray(delivered_rounds, bool)):
        cm = CostModel(st.c_intra,
                       st.c_cross * mults[(t0 + i) % len(mults)])
        intra_b, cross_b = cm.round_bytes(
            topo, dmask, d_params, hierarchical=st.hierarchical,
            client_payload=client_payload, edge_payload=edge_payload)
        cost = cm.round_cost(
            topo, dmask, d_params, hierarchical=st.hierarchical,
            client_payload=client_payload, edge_payload=edge_payload)
        rows[i] = (cost, intra_b, cross_b)
    return rows


# ---------------------------------------------------------------------------
# context construction

def hooks_of(scenario: Optional[Scenario]) -> JitHooks:
    if scenario is None or scenario.jit_hooks is None:
        return JitHooks()
    return scenario.jit_hooks


def supports(flcfg: FLConfig, method: str,
             scenario: Optional[Scenario] = None) -> bool:
    """Can the device engine run this (config, method, scenario)?"""
    if method not in METHODS or flcfg.attack not in UPDATE_ATTACKS:
        return False
    if scenario is not None and not scenario.jittable:
        return False
    if hooks_of(scenario).p_drop > 0 and method not in MASKED_DELIVERY_OK:
        return False
    return True


def resolve_engine(engine: str, flcfg: FLConfig, topo: CloudTopology,
                   method: str, scenario: Optional[Scenario] = None, *,
                   n_devices: Optional[int] = None) -> str:
    """Route a (config, method, scenario) onto a round driver:
    ``"shard"`` (mesh-sharded engine), ``"jit"`` (single-device scan
    engine) or ``"host"`` (legacy loop).

    ``engine="auto"`` prefers the sharded engine when more than one
    device is visible AND the combination is shard-supported, then the
    scan engine, then the host loop — which stays the only driver for
    host-hook scenarios and for dropout with order-statistic
    aggregators. Forcing ``"jit"``/``"shard"`` on an unsupported
    combination raises with the reason (loud failure, never a silent
    mis-aggregation)."""
    from repro.federated import sharded as sharded_mod
    if n_devices is None:
        n_devices = len(jax.devices())
    if engine == "host":
        return "host"
    if engine == "shard":
        reason = sharded_mod.shard_unsupported_reason(
            flcfg, topo, method, scenario, n_devices=n_devices)
        if reason is not None:
            raise ValueError(f"engine='shard' but {reason}")
        return "shard"
    if engine == "jit":
        if not supports(flcfg, method, scenario):
            raise ValueError(
                f"engine='jit' but method={method!r} / "
                f"scenario={getattr(scenario, 'name', None)!r} "
                "is not jittable")
        return "jit"
    if engine != "auto":
        raise ValueError(f"unknown engine {engine!r}; expected "
                         "'auto' | 'shard' | 'jit' | 'host'")
    # the sharded engine trains ALL clients with masking (fixed per-shard
    # shapes), so auto only prefers it at dense participation, where the
    # masked rows are not wasted work; forcing engine="shard" skips this
    # heuristic
    dense = 2 * flcfg.clients_per_round >= topo.n_clients
    if (n_devices > 1 and dense and sharded_mod.shard_unsupported_reason(
            flcfg, topo, method, scenario, n_devices=n_devices) is None):
        return "shard"
    if supports(flcfg, method, scenario):
        return "jit"
    return "host"


def static_from(flcfg: FLConfig, topo: CloudTopology, method: str,
                scenario: Optional[Scenario] = None,
                input_shape: Tuple[int, ...] = (32, 32, 3),
                n_classes: int = 10) -> EngineStatic:
    """Freeze the engine-relevant slice of (FLConfig, topology, scenario)
    into the hashable compile key."""
    if not supports(flcfg, method, scenario):
        raise ValueError(
            f"engine cannot run method={method!r} attack={flcfg.attack!r} "
            f"scenario={getattr(scenario, 'name', None)!r} (host-hook "
            "scenario, unknown method, or dropout with an order-statistic "
            "aggregator) — use the host loop")
    if flcfg.trust_features not in ("scalar", "multi"):
        raise ValueError(f"unknown trust_features {flcfg.trust_features!r}; "
                         "use 'scalar' or 'multi'")
    h = hooks_of(scenario)
    return EngineStatic(
        method=method, cloud_of=tuple(int(c) for c in topo.cloud_of),
        n_clouds=topo.n_clouds, aggregator_cloud=topo.aggregator_cloud,
        input_shape=tuple(input_shape), n_classes=int(n_classes),
        clients_per_round=flcfg.clients_per_round,
        cost_lambda=flcfg.cost_lambda, c_intra=flcfg.c_intra,
        c_cross=flcfg.c_cross, attack=flcfg.attack,
        attack_scale=flcfg.attack_scale, gaussian_sigma=flcfg.gaussian_sigma,
        attack_z=flcfg.attack_z, local_epochs=flcfg.local_epochs,
        local_batch=flcfg.local_batch, lr=flcfg.lr,
        server_lr=flcfg.server_lr, ema_gamma=flcfg.ema_gamma,
        malicious_frac=flcfg.malicious_frac, compressor=flcfg.compressor,
        compress_ratio=flcfg.compress_ratio, qsgd_levels=flcfg.qsgd_levels,
        link_policy=flcfg.link_policy, p_drop=float(h.p_drop),
        malice_warmup=int(h.malice_warmup),
        price_multipliers=tuple(float(m) for m in h.price_multipliers),
        trust_features=flcfg.trust_features)


def draw_malicious(flcfg: FLConfig, n_clients: int, seed: int) -> np.ndarray:
    """The host loop's static adversary draw (shared so engine and
    legacy paths agree on who is malicious for a given seed)."""
    rng = np.random.default_rng(seed)
    n_mal = int(flcfg.malicious_frac * n_clients)
    mal = np.zeros(n_clients, bool)
    mal[rng.choice(n_clients, n_mal, replace=False)] = True
    return mal


def poison_labels(flcfg: FLConfig, data: FederatedData,
                  malicious: np.ndarray, seed: int) -> np.ndarray:
    """The host loop's label_flip poisoning (identity otherwise)."""
    y = np.array(data.client_y)
    if flcfg.attack != "label_flip":
        return y
    rng = np.random.default_rng(seed + 1)
    nc = data.n_classes
    for i in np.nonzero(malicious)[0]:
        y[i] = (y[i] + rng.integers(1, nc, size=y[i].shape)) % nc
    return y


def make_client_data(flcfg: FLConfig, topo: CloudTopology,
                     data: FederatedData, seed: int,
                     malicious: Optional[np.ndarray] = None,
                     poisoned_y: Optional[np.ndarray] = None) -> ClientData:
    """Stage one seed's round-invariant inputs on device."""
    if malicious is None:
        malicious = draw_malicious(flcfg, topo.n_clients, seed)
    if poisoned_y is None:
        poisoned_y = poison_labels(flcfg, data, malicious, seed)
    return ClientData(client_x=jnp.asarray(data.client_x),
                      client_y=jnp.asarray(poisoned_y),
                      ref_x=jnp.asarray(data.ref_x),
                      ref_y=jnp.asarray(data.ref_y),
                      malicious=jnp.asarray(malicious))


# ---------------------------------------------------------------------------
# the compiled engine

@dataclass(frozen=True)
class CompiledEngine:
    """Jitted drivers plus the host-side constants needed to account a
    run (payload vectors, price schedule, last-layer spec)."""
    static: EngineStatic
    step: Callable        # (state, data, t) -> (state, RoundOut)
    run: Callable         # (state, data, rounds) -> (state, RoundOut[T])
    run_batch: Callable   # (state[S], data[S], rounds) -> (state[S], RoundOut[S, T])
    # run_batch with client_x/ref_x/ref_y broadcast (one device copy)
    # and only the per-seed leaves (client_y, malicious) stacked
    run_batch_shared: Callable
    init_state: Callable  # (seed) -> RoundState
    d_params: int
    ll_spec: LastLayerSpec
    client_payload: np.ndarray   # (N,) exact bytes per client uplink
    edge_payload: np.ndarray     # (K,) exact bytes per edge uplink

    def host_round_accounting(self, delivered_rounds: np.ndarray,
                              t0: int = 0) -> np.ndarray:
        """See :func:`host_round_accounting` (module level — shared with
        the sharded engine)."""
        return host_round_accounting(self.static, self.d_params,
                                     self.client_payload, self.edge_payload,
                                     delivered_rounds, t0=t0)


def compiled(static: EngineStatic,
             tap: Optional[TapSpec] = None) -> CompiledEngine:
    """Build (once per (config, tap)) the pure ``round_step`` and its
    jitted step / scan / vmapped-scan drivers.

    ``tap`` — an optional ``repro.telemetry.taps.TapSpec``: when
    enabled, the ``step`` and ``run`` drivers stream ``(t, RoundOut)``
    to the host after every round via an ordered ``jax.debug.callback``
    (install a consumer with ``taps.collecting``); when ``None`` or
    disabled, the build is IDENTICAL to one that never heard of
    telemetry — a disabled tap normalizes to the untapped cache entry,
    so it is the SAME executable, zero added ops. Ordered callbacks
    cannot cross ``vmap``, so the multi-seed batch drivers always run
    untapped and telemetry replays their stacked outputs post-run."""
    if tap is not None and not tap.enabled:
        tap = None
    return _compiled(static, tap)


@lru_cache(maxsize=None)
def _compiled(static: EngineStatic,
              tap: Optional[TapSpec]) -> CompiledEngine:
    st = static
    topo = st.topology()
    n, k = topo.n_clients, topo.n_clouds
    agg = topo.aggregator_cloud
    cloud_of_np = np.array(st.cloud_of)
    cloud_of_j = jnp.asarray(cloud_of_np)
    hier = st.hierarchical

    # template params: shapes only (the real init is per-seed)
    template = client_mod.cnn_init(jax.random.PRNGKey(0), st.input_shape,
                                   st.n_classes)
    d = int(sum(int(np.prod(l.shape)) for l in jax.tree.leaves(template)))
    ll = last_layer_spec(template)
    ll_idx = jnp.asarray(ll.flat_idx)

    lp = build_link_policy(st.compressor, ratio=st.compress_ratio,
                           levels=st.qsgd_levels, link_policy=st.link_policy)
    client_payload, edge_payload = lp.payload_vectors(topo, d,
                                                      hierarchical=hier)
    client_wire_active = ((not lp.intra.is_identity) if hier
                          else lp.any_active)
    edge_wire_active = hier and lp.any_active

    # selection/delivery closures shared with the sharded engine; m_total
    # is resolved statically so the selected set has a fixed population
    # count under jit (see core.selection.exploration_quota)
    _select, m_total = build_select_fn(st)
    _deliver = build_deliver_fn(st)
    _edge_wire = build_edge_wire_fn(lp, k, agg)

    price_arr = jnp.asarray(st.price_multipliers, jnp.float32)
    n_mult = len(st.price_multipliers)
    cp_j = jnp.asarray(client_payload, jnp.float32)
    ep_j = jnp.asarray(edge_payload, jnp.float32)

    f_mal = int(st.malicious_frac * m_total)

    train_sel = jax.vmap(
        lambda p, x, y, kk: client_mod.local_train(
            p, x, y, kk, epochs=st.local_epochs, batch=st.local_batch,
            lr=st.lr),
        in_axes=(None, 0, 0, 0))
    # reference LocalTrain shares the clients' schedule (Eq. 12 rescale
    # preserves the effective server step size)
    train_ref = jax.vmap(
        lambda p, x, y, kk: client_mod.local_train(
            p, x, y, kk, epochs=st.local_epochs, batch=REF_BATCH, lr=st.lr),
        in_axes=(None, 0, 0, None))

    def round_step(state: RoundState, data: ClientData, t
                   ) -> Tuple[RoundState, RoundOut]:
        # phase scopes (jax.named_scope) label the emitted ops for
        # profiler traces / HLO metadata — they change nothing at runtime
        t = jnp.asarray(t, jnp.int32)
        key = round_key(state.seed, t)
        mult = price_arr[jnp.mod(t, n_mult)] if n_mult > 1 else price_arr[0]
        c_cross_t = st.c_cross * mult

        with jax.named_scope("round.select"):
            sel = _select(state.rep_ema, c_cross_t,
                          jax.random.fold_in(key, _FOLD_SELECT))
            delivered = _deliver(sel, jax.random.fold_in(key, _FOLD_DROPOUT))
            sel_idx = jnp.nonzero(sel, size=m_total, fill_value=0)[0]
            valid = delivered[sel_idx]                   # (m_total,) bool

        # local training over the fixed-size selected set (dropped
        # clients train too — fixed shapes — but are masked below)
        with jax.named_scope("round.train"):
            keys = jax.random.split(key, n)
            upd_tree = train_sel(state.params, data.client_x[sel_idx],
                                 data.client_y[sel_idx], keys[sel_idx])
            flat_sel = ravel_rows(upd_tree)              # (m_total, D)

        # update-level attacks on this round's ACTIVE malicious clients
        with jax.named_scope("round.attack"):
            mal = data.malicious
            if st.malice_warmup > 0:
                mal = mal & (t >= st.malice_warmup)
            mal_sel = mal[sel_idx] & valid
            flat_sel = apply_update_attack(
                st.attack, flat_sel, mal_sel, key, sigma=st.gaussian_sigma,
                scale=st.attack_scale, z=st.attack_z,
                valid=valid if st.p_drop > 0 else None)

        # client uplink wire (EF residuals gathered/scattered from state)
        res_client = state.res_client
        if client_wire_active:
            with jax.named_scope("round.compress"):
                ckey = jax.random.fold_in(key, _FOLD_CLIENT_WIRE)
                cur = res_client[sel_idx]
                if hier:   # every client→edge hop is intra-class
                    flat_sel, cur = ef_step_masked(lp.intra, flat_sel, cur,
                                                   valid, ckey, sel_idx)
                else:      # flat path: intra or cross by co-location
                    same = cloud_of_j[sel_idx] == agg
                    flat_sel, cur = ef_step_masked(
                        lp.intra, flat_sel, cur, valid & same,
                        jax.random.fold_in(ckey, 0), sel_idx)
                    flat_sel, cur = ef_step_masked(
                        lp.cross, flat_sel, cur, valid & ~same,
                        jax.random.fold_in(ckey, 1), sel_idx)
                res_client = res_client.at[sel_idx].set(cur)

        # trust statistics read the attacked+compressed wire view
        if st.p_drop > 0:
            flat_sel = jnp.where(valid[:, None], flat_sel, 0.0)
        ll_sel = flat_sel[:, ll_idx]

        res_edge = state.res_edge
        new_rep = state.rep_ema
        new_feat_sep = state.feat_sep
        feat_w = jnp.zeros((0,), jnp.float32)
        with jax.named_scope("round.aggregate"):
            if hier:
                # compact Eq. 5–13: the same pipeline as
                # core.cost_trustfl_aggregate, but over the (m_total, D)
                # selected rows instead of a zero-padded (N, D) scatter —
                # aggregation traffic scales with the round's participants,
                # not the fleet (N/m× less memory movement, and the vmapped
                # multi-seed batch stays cache-resident)
                eps = 1e-12
                f32 = flat_sel.dtype
                ref_tree = train_ref(state.params, data.ref_x, data.ref_y,
                                     key)
                ref_flat = ravel_rows(ref_tree)
                ref_ll = ref_flat[:, ll_idx]
                sel_cloud = cloud_of_j[sel_idx]                   # (m,)
                onehot = jax.nn.one_hot(sel_cloud, k, dtype=f32)  # (m, K)
                w = valid.astype(f32)
                ref_ll_sel = ref_ll[sel_cloud]                    # (m, L)

                # Eq. 7 with the median-damped norm factor (see core)
                gbar = (w @ ll_sel) / jnp.maximum(jnp.sum(w), 1.0)
                norms = jnp.linalg.norm(ll_sel, axis=1)
                med = jnp.nanmedian(jnp.where(w > 0, norms, jnp.nan))
                damp = jnp.minimum(1.0,
                                   (med / jnp.maximum(norms, eps)) ** 2)
                damp = jnp.where(jnp.isnan(damp), 1.0, damp)
                phi = gradient_contribution(ll_sel, gbar) * damp * w

                # multi-feature gate (core.features): phi scaled by the
                # adaptively-weighted feature vector of each delivered
                # row; separability labels come from the PREVIOUS
                # reputation EMA (pre-Eq. 8–9 update)
                if st.multi_features:
                    feats = feats_mod.client_features(
                        ll_sel, ref_ll_sel, gbar, med, w, eps)
                    sep_round = feats_mod.separability(feats, w, eps)
                    new_feat_sep = (
                        feats_mod.FEAT_SEP_RHO * state.feat_sep
                        + (1.0 - feats_mod.FEAT_SEP_RHO) * sep_round)
                    feat_w = feats_mod.feature_weights(new_feat_sep)
                    phi = phi * feats_mod.gate(feats, new_feat_sep)

                # Eq. 8–9: normalize over the round (non-selected φ are
                # 0), EMA only for delivered participants
                total = jnp.sum(phi)
                r = jnp.where(total > eps, phi / jnp.maximum(total, eps),
                              1.0 / n)
                rep_sel = (st.ema_gamma * state.rep_ema[sel_idx]
                           + (1.0 - st.ema_gamma) * r)
                rep_sel = jnp.where(valid, rep_sel, state.rep_ema[sel_idx])
                new_rep = state.rep_ema.at[sel_idx].set(rep_sel)

                # Eq. 11: trust vs. the client's own cloud reference
                dots = jnp.sum(ll_sel * ref_ll_sel, axis=1)
                cos = dots / jnp.maximum(
                    norms * jnp.linalg.norm(ref_ll_sel, axis=1), eps)
                ts = jax.nn.relu(cos) * rep_sel * w

                # Eq. 12: rescale to own-cloud reference norm
                ref_norms = jnp.linalg.norm(ref_flat, axis=1)     # (K,)
                g_tilde = flat_sel * (ref_norms[sel_cloud] / jnp.maximum(
                    jnp.linalg.norm(flat_sel, axis=1), eps))[:, None]

                # Eq. 13 per cloud (intra-cloud phase, Eq. 5)
                ts_cloud = onehot.T @ ts                          # (K,)
                cloud_aggs = (onehot.T @ (g_tilde * ts[:, None])
                              / jnp.maximum(ts_cloud, eps)[:, None])
                if edge_wire_active:
                    active = (onehot.T @ w > 0)[:, None]
                    cloud_aggs, res_edge = _edge_wire(
                        cloud_aggs, res_edge, active,
                        jax.random.fold_in(key, _FOLD_EDGE_WIRE))
                # empty/zero-trust clouds fall back to their reference
                cloud_aggs = jnp.where((ts_cloud > eps)[:, None],
                                       cloud_aggs, ref_flat)

                # Eq. 6: cross-cloud phase, β_k from the global reference
                beta = cloud_trust(cloud_aggs, jnp.mean(ref_flat, axis=0))
                update = beta @ cloud_aggs
            else:
                u = flat_sel
                if st.method == "fedavg":
                    if st.p_drop > 0:
                        w = valid.astype(u.dtype)
                        update = (w @ u) / jnp.maximum(jnp.sum(w), 1.0)
                    else:
                        update = fedavg(u)
                elif st.method == "krum":
                    update = krum(u, f_mal,
                                  multi=max(1, m_total - f_mal - 2))
                elif st.method == "trimmed_mean":
                    update = trimmed_mean(u,
                                          trim_frac=st.malicious_frac / 2)
                elif st.method == "median":
                    update = coordinate_median(u)
                else:  # fltrust — zero (dropped) rows get ts=0, so it's
                       # already masked-delivery safe
                    ref_tree = train_ref(state.params, data.ref_x,
                                         data.ref_y, key)
                    ref_flat = ravel_rows(ref_tree)
                    update = fltrust(u, jnp.mean(ref_flat, axis=0))

            # apply: w <- w - eta * g  (g is a model delta)
            delta = unflatten_like(update * st.server_lr, state.params)
            params = jax.tree.map(lambda w, g: w - g, state.params, delta)

        with jax.named_scope("round.account"):
            # byte-exact wire accounting (float32 in-graph mirror; the
            # host drivers re-derive float64 totals from `delivered`)
            intra_b, cross_b = round_bytes_jax(delivered, cloud_of_j, agg,
                                               cp_j, ep_j,
                                               hierarchical=hier)
            cost = (intra_b * st.c_intra + cross_b * c_cross_t) / _GB
            digest = tree_l2(params)

        new_state = RoundState(
            params=params, rep_ema=new_rep, res_client=res_client,
            res_edge=res_edge, cum_cost=state.cum_cost + cost,
            cum_intra_bytes=state.cum_intra_bytes + intra_b,
            cum_cross_bytes=state.cum_cross_bytes + cross_b,
            feat_sep=new_feat_sep, seed=state.seed)
        out = RoundOut(delivered=delivered, rep=new_rep, cost=cost,
                       intra_bytes=intra_b, cross_bytes=cross_b,
                       params_l2=digest, feat_weights=feat_w)
        return new_state, out

    # the tapped step feeds ONLY the unbatched drivers; when the tap is
    # off/absent this is round_step itself and nothing changes
    tapped_step = taps_mod.instrument(round_step, tap)

    step = jax.jit(tapped_step)

    def _scan(state, data, ts):
        return jax.lax.scan(lambda c, t: tapped_step(c, data, t), state, ts)

    def _scan_untapped(state, data, ts):
        return jax.lax.scan(lambda c, t: round_step(c, data, t), state, ts)

    scan_jit = jax.jit(_scan)
    # batch drivers vmap the UNTAPPED scan (ordered callbacks cannot
    # cross vmap; multi-seed events are replayed post-run instead)
    scan_batch_jit = jax.jit(jax.vmap(_scan_untapped, in_axes=(0, 0, None)))
    # seeds sharing one dataset: broadcast the sample arrays instead of
    # stacking S copies (labels and the adversary draw stay per-seed)
    _shared_axes = ClientData(client_x=None, client_y=0, ref_x=None,
                              ref_y=None, malicious=0)
    scan_batch_shared_jit = jax.jit(
        jax.vmap(_scan_untapped, in_axes=(0, _shared_axes, None)))

    def run(state: RoundState, data: ClientData, rounds: int):
        """lax.scan the engine over ``rounds`` rounds — one device call."""
        return scan_jit(state, data, jnp.arange(rounds, dtype=jnp.int32))

    def run_batch(states: RoundState, datas: ClientData, rounds: int):
        """vmap(run): stacked states/datas with a leading seeds axis."""
        return scan_batch_jit(states, datas,
                              jnp.arange(rounds, dtype=jnp.int32))

    def run_batch_shared(states: RoundState, data: ClientData, rounds: int):
        """vmap(run) over seeds sharing one dataset: ``data`` carries
        unstacked (N, ...) sample/reference arrays and stacked (S, ...)
        labels + malicious masks."""
        return scan_batch_shared_jit(states, data,
                                     jnp.arange(rounds, dtype=jnp.int32))

    def init_state(seed: int) -> RoundState:
        return init_round_state(st, d, seed,
                                client_wire_active=client_wire_active,
                                edge_wire_active=edge_wire_active)

    return CompiledEngine(static=st, step=step, run=run,
                          run_batch=run_batch,
                          run_batch_shared=run_batch_shared,
                          init_state=init_state,
                          d_params=d, ll_spec=ll,
                          client_payload=client_payload,
                          edge_payload=edge_payload)

"""Mesh-sharded round engine: the client population laid out over a
``("cloud", "client")`` device mesh via ``shard_map``, with Eq. 5–13
hierarchical aggregation realized as a two-stage reduction — intra-cloud
``psum`` over the ``client`` axis, then a cross-cloud combine over the
``cloud`` axis — mirroring the production train step's ``two_phase``
strategy (``repro.train.steps``).

This is the physical realization of the paper's topology: clouds map to
mesh columns (cheap intra-column reductions = intra-cloud traffic),
the cross-column combine is the single per-cloud egress hop. Each shard
owns a contiguous block of clients and keeps their training data and
error-feedback residuals resident; per round it

* evaluates Eq. 10 selection + delivery REPLICATED on the full (N,)
  reputation (tiny, and bit-identical to the single-device engine —
  the closures are shared, see ``engine.build_select_fn``);
* trains ALL of its local clients with fixed shapes and masks the
  non-selected rows out of every statistic ("masked local training"):
  under jit the selected subset has no static per-shard size, so the
  sharded engine's sweet spot is dense participation (fleet sweeps,
  ``clients_per_round`` ≈ N) — at sparse participation the single-
  device engine trains fewer rows and ``engine="auto"`` prefers it;
* applies update attacks and per-link compression per shard (honest-
  statistics adversaries get their moments from masked global
  reductions over the same row set the single-device engine sees);
* aggregates hierarchically in two stages and accounts bytes/$ from the
  replicated delivered mask — the SAME ``round_bytes_jax`` reduction as
  the scan engine, so cost accounting stays byte-exact: intra-column
  reductions are billed at ``c_intra``, the cross-column combine at the
  (possibly scheduled) ``c_cross``.

Support surface (``shard_unsupported_reason``): all six methods run, but
configurations whose randomness or statistics are *matrix-shaped* are
rejected with a clear error instead of silently mis-aggregating —
``gaussian`` draws an (m, D) noise tensor and ``min_max`` bisects on the
pairwise Gram of the selected matrix; their values depend on row
position in the selected matrix, which no longer exists as one array.
(``qsgd`` used to be in this list, but its rounding noise is now keyed
per SENDER — ``fold_in(client_id)`` — so it shards exactly; see
``repro.compress.qsgd``.) Order-statistic aggregators (krum /
trimmed_mean / median) ARE supported: the (m_total, D) selected matrix is
re-materialized replicated via a slot-scatter psum (rows land in the
exact ``sel_idx`` order of the scan engine), which costs one m×D
all-reduce — acceptable because m ≪ N is the only regime those baselines
run at.

Parity contract (tests/test_sharded.py): on a 1×1 mesh the sharded
engine matches the single-device scan engine to documented fp tolerance
(selection masks, delivered masks and byte/cost accounting exactly;
params/reputation to ~1e-4 relative, the bound the tests enforce —
psum partial sums associate differently than one flat matmul, so
bitwise equality is not promised).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compress import build_link_policy, ef_step_masked
from repro.configs.base import FLConfig
from repro.core import CloudTopology
from repro.core import features as feats_mod
from repro.core.cost import round_bytes_jax
from repro.core.robust import coordinate_median, krum, trimmed_mean
from repro.core.shapley import gradient_contribution
from repro.core.trust import cloud_trust
from repro.data.pipeline import FederatedData
from repro.federated import client as client_mod
from repro.federated import engine as engine_mod
from repro.federated.engine import (ClientData, EngineStatic, LastLayerSpec,
                                    MASKED_DELIVERY_OK, METHODS, REF_BATCH,
                                    RoundOut, RoundState, _FOLD_CLIENT_WIRE,
                                    _FOLD_DROPOUT, _FOLD_EDGE_WIRE,
                                    _FOLD_SELECT, build_deliver_fn,
                                    build_edge_wire_fn, build_select_fn,
                                    hooks_of, host_round_accounting,
                                    init_round_state, last_layer_spec,
                                    ravel_rows, round_key, tree_l2,
                                    unflatten_like)
from repro.scenarios.base import Scenario

Array = jax.Array

_GB = 1024.0 ** 3
AXES = ("cloud", "client")

# attacks whose per-round transform decomposes over client shards: either
# per-row (sign_flip / scaling / the data-level label_flip) or driven by
# masked GLOBAL moments that psum/all_gather cleanly (alie / alie_norm /
# ipm / collusion). ``gaussian`` (an (m, D) noise tensor) and ``min_max``
# (bisection on the selected matrix's pairwise Gram) are matrix-shaped —
# scan engine only.
SHARD_ATTACKS = ("none", "label_flip", "sign_flip", "scaling", "alie",
                 "alie_norm", "ipm", "collusion")

# ``topk`` is per-row deterministic and ``qsgd`` keys its rounding noise
# per sender (fold_in(client_id), see repro.compress.qsgd) — both shard
# exactly.
SHARD_COMPRESSORS = ("none", "topk", "qsgd")


# ---------------------------------------------------------------------------
# mesh construction / support gating

def mesh_axes(n_clouds: int, n_clients: int,
              n_devices: Optional[int] = None) -> Optional[Tuple[int, int]]:
    """Factor the device count into ``(cloud, client)`` axis sizes:
    the cloud axis takes the largest common divisor so mesh columns own
    whole clouds (intra-cloud psums never cross columns). ``None`` when
    the population does not tile the devices."""
    if n_devices is None:
        n_devices = len(jax.devices())
    if n_devices < 1 or n_clients % n_devices != 0:
        return None
    kc = math.gcd(n_devices, n_clouds)
    return kc, n_devices // kc


def client_mesh(n_clouds: int, n_clients: int,
                n_devices: Optional[int] = None) -> Mesh:
    """``("cloud", "client")`` mesh over the visible devices."""
    if n_devices is None:
        n_devices = len(jax.devices())
    ax = mesh_axes(n_clouds, n_clients, n_devices)
    if ax is None:
        raise ValueError(
            f"cannot tile {n_clients} clients over {n_devices} devices")
    return jax.make_mesh(ax, AXES)


def _even_contiguous(topo: CloudTopology) -> bool:
    """The sharded layout requires the even contiguous client→cloud map
    (``CloudTopology.even``): cloud k owns clients [k·n_k, (k+1)·n_k)."""
    n, k = topo.n_clients, topo.n_clouds
    if n % k != 0:
        return False
    return bool(np.array_equal(topo.cloud_of,
                               np.arange(n) // (n // k)))


def shard_unsupported_reason(flcfg: FLConfig, topo: CloudTopology,
                             method: str,
                             scenario: Optional[Scenario] = None, *,
                             n_devices: Optional[int] = None
                             ) -> Optional[str]:
    """``None`` when the sharded engine can run this combination, else a
    human-readable reason (used verbatim in the raised error — the
    engine must refuse loudly, never silently mis-aggregate)."""
    if method not in METHODS:
        return f"unknown method {method!r}"
    if scenario is not None and not scenario.jittable:
        return (f"scenario {scenario.name!r} has host-only hooks "
                "(no JitHooks declaration)")
    if hooks_of(scenario).p_drop > 0 and method not in MASKED_DELIVERY_OK:
        return (f"dropout with order-statistic aggregator {method!r} "
                "(zero rows would count as clients)")
    if flcfg.attack not in SHARD_ATTACKS:
        return (f"attack {flcfg.attack!r} is matrix-shaped (randomness or "
                "statistics tied to the selected matrix's layout) — use "
                "the scan engine")
    if flcfg.compressor not in SHARD_COMPRESSORS:
        return (f"compressor {flcfg.compressor!r} is not "
                "shard-decomposable — use the scan engine")
    if not _even_contiguous(topo):
        return ("client→cloud layout is not the even contiguous "
                "CloudTopology.even map")
    if n_devices is None:
        n_devices = len(jax.devices())
    if mesh_axes(topo.n_clouds, topo.n_clients, n_devices) is None:
        return (f"{topo.n_clients} clients do not tile "
                f"{n_devices} devices")
    return None


def supports_shard(flcfg: FLConfig, method: str,
                   scenario: Optional[Scenario] = None, *,
                   topo: Optional[CloudTopology] = None,
                   n_devices: Optional[int] = None) -> bool:
    if topo is None:
        topo = CloudTopology.even(flcfg.n_clouds, flcfg.clients_per_cloud)
    return shard_unsupported_reason(flcfg, topo, method, scenario,
                                    n_devices=n_devices) is None


@dataclass(frozen=True)
class ShardStatic:
    """Compile key: the engine static plus the mesh factorization."""
    static: EngineStatic
    kc: int
    pc: int


def static_from_shard(flcfg: FLConfig, topo: CloudTopology, method: str,
                      scenario: Optional[Scenario] = None,
                      input_shape: Tuple[int, ...] = (32, 32, 3),
                      n_classes: int = 10, *,
                      n_devices: Optional[int] = None) -> ShardStatic:
    reason = shard_unsupported_reason(flcfg, topo, method, scenario,
                                      n_devices=n_devices)
    if reason is not None:
        raise ValueError(f"sharded engine cannot run this config: {reason}")
    kc, pc = mesh_axes(topo.n_clouds, topo.n_clients, n_devices)
    st = engine_mod.static_from(flcfg, topo, method, scenario,
                                input_shape=input_shape,
                                n_classes=n_classes)
    return ShardStatic(static=st, kc=kc, pc=pc)


# ---------------------------------------------------------------------------
# shard_map across jax versions (same dispatch as repro.train.steps; the
# sharded engine is fully manual over both axes, so the 0.4.x legacy
# entry point with check_rep=False is numerically identical)

def _shard_map(f, *, mesh, in_specs, out_specs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as legacy_shard_map
    return legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# the compiled sharded engine

@dataclass(frozen=True)
class CompiledShard:
    """Duck-types the scan engine's ``CompiledEngine`` driver surface
    (step / run / init_state / host_round_accounting) so ``FLServer``
    and the simulation drivers treat both engines uniformly."""
    shard_static: ShardStatic
    mesh: Mesh
    step: Callable        # (state, data, t) -> (state, RoundOut)
    run: Callable         # (state, data, rounds) -> (state, RoundOut[T])
    init_state: Callable  # (seed) -> RoundState (mesh-placed)
    stage_data: Callable  # ClientData -> ClientData (mesh-placed)
    d_params: int
    ll_spec: LastLayerSpec
    client_payload: np.ndarray
    edge_payload: np.ndarray

    @property
    def static(self) -> EngineStatic:
        return self.shard_static.static

    def host_round_accounting(self, delivered_rounds: np.ndarray,
                              t0: int = 0) -> np.ndarray:
        return host_round_accounting(self.static, self.d_params,
                                     self.client_payload, self.edge_payload,
                                     delivered_rounds, t0=t0)


def _psum(x, axes=AXES):
    return jax.lax.psum(x, axes)


def _masked_moments(x: Array, w: Array, eps: float = 1e-12
                    ) -> Tuple[Array, Array]:
    """Global per-coordinate (mean, std) over rows with weight ``w`` —
    the shard-decomposed twin of ``core.attacks._honest_moments`` (two
    psum stages: sums for the mean, then centered squares)."""
    n = jnp.maximum(_psum(jnp.sum(w)), 1.0)
    mean = _psum(w @ x) / n
    var = _psum(jnp.sum(((x - mean) ** 2) * w[:, None], axis=0)) / n
    return mean, jnp.sqrt(jnp.maximum(var, eps * eps))


def _shard_attack(name: str, flat: Array, mal: Array, honest_w: Array,
                  *, scale: float, z: float) -> Array:
    """Per-shard update attacks over the local rows. ``mal`` is the
    round's ACTIVE malicious mask restricted to delivered rows;
    ``honest_w`` weights the delivered honest rows (the same set the
    scan engine's ``_honest_moments`` sees)."""
    if name in ("none", "label_flip"):
        return flat
    rm = mal[:, None]
    if name == "sign_flip":
        return jnp.where(rm, -scale * flat, flat)
    if name == "scaling":
        return jnp.where(rm, scale * flat, flat)
    if name == "alie":
        mean, std = _masked_moments(flat, honest_w)
        return jnp.where(rm, mean - z * std, flat)
    if name == "alie_norm":
        eps = 1e-12
        mean, std = _masked_moments(flat, honest_w)
        point = mean - z * std
        # honest MEDIAN norm via the same all_gather idiom as Eq. 7's
        # median damp — (N,)-sized, replicated on every shard
        norms = jnp.linalg.norm(flat, axis=1)
        all_hn = jax.lax.all_gather(
            jnp.where(honest_w > 0, norms, jnp.nan), AXES, tiled=True)
        med = jnp.nanmedian(all_hn)
        med = jnp.where(jnp.isnan(med) | ~(med > 0), 1.0, med)
        point = point * (med / jnp.maximum(jnp.linalg.norm(point), eps))
        return jnp.where(rm, point, flat)
    if name == "ipm":
        mean, _ = _masked_moments(flat, honest_w)
        return jnp.where(rm, -scale * mean, flat)
    if name == "collusion":
        w = mal.astype(flat.dtype)
        n_m = jnp.maximum(_psum(jnp.sum(w)), 1.0)
        mal_mean = _psum(w @ flat) / n_m
        return jnp.where(rm, -scale * mal_mean, flat)
    raise ValueError(f"attack {name!r} is not shard-decomposable")


@lru_cache(maxsize=None)
def compiled_sharded(shard_static: ShardStatic) -> CompiledShard:
    """Build (once per (config, mesh factorization)) the per-shard round
    program and its jitted step / scan drivers."""
    st = shard_static.static
    kc, pc = shard_static.kc, shard_static.pc
    ndev = kc * pc
    topo = st.topology()
    n, k = topo.n_clients, topo.n_clouds
    agg = topo.aggregator_cloud
    n_k = n // k                       # even contiguous layout (gated)
    n_loc = n // ndev
    hier = st.hierarchical
    mesh = client_mesh(k, n, ndev)

    template = client_mod.cnn_init(jax.random.PRNGKey(0), st.input_shape,
                                   st.n_classes)
    d = int(sum(int(np.prod(l.shape)) for l in jax.tree.leaves(template)))
    ll = last_layer_spec(template)
    ll_idx = jnp.asarray(ll.flat_idx)

    lp = build_link_policy(st.compressor, ratio=st.compress_ratio,
                           levels=st.qsgd_levels, link_policy=st.link_policy)
    client_payload, edge_payload = lp.payload_vectors(topo, d,
                                                      hierarchical=hier)
    client_wire_active = ((not lp.intra.is_identity) if hier
                          else lp.any_active)
    edge_wire_active = hier and lp.any_active

    _select, m_total = build_select_fn(st)
    _deliver = build_deliver_fn(st)
    _edge_wire = build_edge_wire_fn(lp, k, agg)

    price_arr = jnp.asarray(st.price_multipliers, jnp.float32)
    n_mult = len(st.price_multipliers)
    cp_j = jnp.asarray(client_payload, jnp.float32)
    ep_j = jnp.asarray(edge_payload, jnp.float32)
    cloud_of_j = jnp.asarray(np.array(st.cloud_of))
    f_mal = int(st.malicious_frac * m_total)

    train_loc = jax.vmap(
        lambda p, x, y, kk: client_mod.local_train(
            p, x, y, kk, epochs=st.local_epochs, batch=st.local_batch,
            lr=st.lr),
        in_axes=(None, 0, 0, 0))
    train_ref = jax.vmap(
        lambda p, x, y, kk: client_mod.local_train(
            p, x, y, kk, epochs=st.local_epochs, batch=REF_BATCH, lr=st.lr),
        in_axes=(None, 0, 0, None))

    def _shard_offset():
        """First global client id owned by this shard — the block layout
        of ``P(("cloud", "client"))`` on the leading client axis."""
        shard = (jax.lax.axis_index("cloud") * pc
                 + jax.lax.axis_index("client"))
        return shard * n_loc

    def round_step_local(state: RoundState, data: ClientData, t
                         ) -> Tuple[RoundState, RoundOut]:
        """One round, per-shard view: ``data`` leaves carry this shard's
        (n_loc, ...) client block; (N,)-sized selection state is
        replicated."""
        t = jnp.asarray(t, jnp.int32)
        key = round_key(state.seed, t)
        mult = price_arr[jnp.mod(t, n_mult)] if n_mult > 1 else price_arr[0]
        c_cross_t = st.c_cross * mult
        eps = 1e-12

        # replicated selection + delivery on the full fleet (identical
        # closures — and therefore identical masks — to the scan engine)
        sel = _select(state.rep_ema, c_cross_t,
                      jax.random.fold_in(key, _FOLD_SELECT))
        delivered = _deliver(sel, jax.random.fold_in(key, _FOLD_DROPOUT))

        i0 = _shard_offset()
        gids = i0 + jnp.arange(n_loc)
        valid = jax.lax.dynamic_slice(delivered, (i0,), (n_loc,))
        rep_loc = jax.lax.dynamic_slice(state.rep_ema, (i0,), (n_loc,))
        w = valid.astype(jnp.float32)

        # masked local training: every local client trains (fixed
        # shapes), each with the same per-client key as the scan engine
        keys = jax.random.split(key, n)
        keys_loc = jax.lax.dynamic_slice(keys, (i0, 0), (n_loc, 2))
        upd_tree = train_loc(state.params, data.client_x, data.client_y,
                             keys_loc)
        flat = ravel_rows(upd_tree)                      # (n_loc, D)

        # update attacks on this round's ACTIVE malicious clients
        mal = data.malicious
        if st.malice_warmup > 0:
            mal = mal & (t >= st.malice_warmup)
        mal_loc = mal & valid
        flat = _shard_attack(st.attack, flat, mal_loc, (~mal & valid
                                                        ).astype(jnp.float32),
                             scale=st.attack_scale, z=st.attack_z)

        # client uplink wire (EF residuals live with the shard)
        res_client = state.res_client
        if client_wire_active:
            ckey = jax.random.fold_in(key, _FOLD_CLIENT_WIRE)
            if hier:       # every client→edge hop is intra-class
                flat, res_client = ef_step_masked(lp.intra, flat,
                                                  res_client, valid, ckey,
                                                  gids)
            else:          # flat path: intra or cross by co-location
                same = jax.lax.dynamic_slice(
                    (cloud_of_j == agg), (i0,), (n_loc,))
                flat, res_client = ef_step_masked(
                    lp.intra, flat, res_client, valid & same,
                    jax.random.fold_in(ckey, 0), gids)
                flat, res_client = ef_step_masked(
                    lp.cross, flat, res_client, valid & ~same,
                    jax.random.fold_in(ckey, 1), gids)

        # everything downstream reads the masked wire view: rows that
        # did not deliver (or were never selected) are exact zeros
        flat = jnp.where(w[:, None] > 0, flat, 0.0)
        ll_loc = flat[:, ll_idx]

        res_edge = state.res_edge
        new_rep = state.rep_ema
        new_feat_sep = state.feat_sep
        feat_w = jnp.zeros((0,), jnp.float32)
        if hier:
            f32 = flat.dtype
            ref_tree = train_ref(state.params, data.ref_x, data.ref_y, key)
            ref_flat = ravel_rows(ref_tree)
            ref_ll = ref_flat[:, ll_idx]
            cloud_loc = gids // n_k                      # (n_loc,)
            onehot = jax.nn.one_hot(cloud_loc, k, dtype=f32)
            ref_ll_loc = ref_ll[cloud_loc]

            # Eq. 7 with the median-damped norm factor: global gbar and
            # the delivered-norm median from cheap (N,)-sized collectives
            wsum = _psum(jnp.sum(w))
            gbar = _psum(w @ ll_loc) / jnp.maximum(wsum, 1.0)
            norms = jnp.linalg.norm(ll_loc, axis=1)
            all_norms = jax.lax.all_gather(
                jnp.where(w > 0, norms, jnp.nan), AXES, tiled=True)
            med = jnp.nanmedian(all_norms)
            damp = jnp.minimum(1.0, (med / jnp.maximum(norms, eps)) ** 2)
            damp = jnp.where(jnp.isnan(damp), 1.0, damp)
            phi = gradient_contribution(ll_loc, gbar) * damp * w

            # multi-feature gate (core.features): features are per-row
            # (shards own whole rows, gbar/med already globally reduced),
            # the separability statistics reduce in ONE psum of the
            # stacked (6, F) sums, and the EMA/weights stay replicated
            if st.multi_features:
                feats = feats_mod.client_features(ll_loc, ref_ll_loc,
                                                  gbar, med, w, eps)
                sums = _psum(feats_mod.separability_sums(feats, w))
                sep_round = feats_mod.separability_from_sums(sums, eps)
                new_feat_sep = (
                    feats_mod.FEAT_SEP_RHO * state.feat_sep
                    + (1.0 - feats_mod.FEAT_SEP_RHO) * sep_round)
                feat_w = feats_mod.feature_weights(new_feat_sep)
                phi = phi * feats_mod.gate(feats, new_feat_sep)

            # Eq. 8–9
            total = _psum(jnp.sum(phi))
            r = jnp.where(total > eps, phi / jnp.maximum(total, eps),
                          1.0 / n)
            rep_new_loc = (st.ema_gamma * rep_loc
                           + (1.0 - st.ema_gamma) * r)
            rep_new_loc = jnp.where(valid, rep_new_loc, rep_loc)
            new_rep = jax.lax.all_gather(rep_new_loc, AXES, tiled=True)

            # Eq. 11: trust vs. the client's own cloud reference
            dots = jnp.sum(ll_loc * ref_ll_loc, axis=1)
            cos = dots / jnp.maximum(
                norms * jnp.linalg.norm(ref_ll_loc, axis=1), eps)
            ts = jax.nn.relu(cos) * rep_new_loc * w

            # Eq. 12: rescale to own-cloud reference norm
            ref_norms = jnp.linalg.norm(ref_flat, axis=1)
            g_tilde = flat * (ref_norms[cloud_loc] / jnp.maximum(
                jnp.linalg.norm(flat, axis=1), eps))[:, None]

            # Eq. 5/13: TWO-STAGE reduction. Stage 1 (intra-cloud): each
            # shard's per-cloud partial sums psum over the client axis —
            # a cloud's clients all live in one mesh column, so this
            # completes the cloud aggregates without crossing columns.
            # Stage 2 (cross-cloud): one combine over the cloud axis
            # (each cloud's rows are nonzero in exactly one column).
            ts_cloud = _psum(onehot.T @ ts)                       # (K,)
            cnt_cloud = _psum(onehot.T @ w)                       # (K,)
            partial = onehot.T @ (g_tilde * ts[:, None])          # (K, D)
            cloud_sums = jax.lax.psum(partial, "client")          # stage 1
            cloud_sums = jax.lax.psum(cloud_sums, "cloud")        # stage 2
            cloud_aggs = cloud_sums / jnp.maximum(ts_cloud, eps)[:, None]
            if edge_wire_active:
                # edge→global wire on the (now replicated) aggregates —
                # the SAME shared EF closure as the scan engine, only
                # `active` is derived from the psum'd per-cloud counts
                active = (cnt_cloud > 0)[:, None]
                cloud_aggs, res_edge = _edge_wire(
                    cloud_aggs, res_edge, active,
                    jax.random.fold_in(key, _FOLD_EDGE_WIRE))
            # empty/zero-trust clouds fall back to their reference update
            cloud_aggs = jnp.where((ts_cloud > eps)[:, None], cloud_aggs,
                                   ref_flat)

            # Eq. 6: cross-cloud phase, β_k from the global reference
            beta = cloud_trust(cloud_aggs, jnp.mean(ref_flat, axis=0))
            update = beta @ cloud_aggs
        else:
            if st.method == "fedavg":
                update = _psum(w @ flat) / jnp.maximum(_psum(jnp.sum(w)),
                                                       1.0)
            elif st.method == "fltrust":
                ref_tree = train_ref(state.params, data.ref_x, data.ref_y,
                                     key)
                ref = jnp.mean(ravel_rows(ref_tree), axis=0)
                refn = jnp.linalg.norm(ref)
                norms = jnp.linalg.norm(flat, axis=1)
                cos = (flat @ ref) / jnp.maximum(norms * refn, eps)
                ts = jax.nn.relu(cos) * w
                g_tilde = flat * (refn / jnp.maximum(norms, eps))[:, None]
                update = (_psum(ts @ g_tilde)
                          / jnp.maximum(_psum(jnp.sum(ts)), eps))
            else:
                # order statistics need the selected matrix as ONE array:
                # re-materialize it replicated via a slot-scatter psum —
                # rows land at their cumsum(sel) position, i.e. the exact
                # sel_idx order of the scan engine
                sel_loc = jax.lax.dynamic_slice(sel, (i0,), (n_loc,))
                slot = jnp.cumsum(sel) - 1                       # (N,)
                slot_loc = jnp.clip(
                    jax.lax.dynamic_slice(slot, (i0,), (n_loc,)), 0,
                    m_total - 1)
                buf = jnp.zeros((m_total, flat.shape[1]), flat.dtype)
                buf = buf.at[slot_loc].add(
                    jnp.where(sel_loc[:, None], flat, 0.0))
                u = _psum(buf)                                   # (m, D)
                if st.method == "krum":
                    update = krum(u, f_mal,
                                  multi=max(1, m_total - f_mal - 2))
                elif st.method == "trimmed_mean":
                    update = trimmed_mean(u,
                                          trim_frac=st.malicious_frac / 2)
                else:
                    update = coordinate_median(u)

        # apply: w <- w - eta * g  (replicated)
        delta = unflatten_like(update * st.server_lr, state.params)
        params = jax.tree.map(lambda p, g: p - g, state.params, delta)

        # byte-exact wire accounting from the replicated delivered mask —
        # the same reduction as the scan engine, bit-identical masks in,
        # bit-identical bytes out
        intra_b, cross_b = round_bytes_jax(delivered, cloud_of_j, agg,
                                           cp_j, ep_j, hierarchical=hier)
        cost = (intra_b * st.c_intra + cross_b * c_cross_t) / _GB

        new_state = RoundState(
            params=params, rep_ema=new_rep, res_client=res_client,
            res_edge=res_edge, cum_cost=state.cum_cost + cost,
            cum_intra_bytes=state.cum_intra_bytes + intra_b,
            cum_cross_bytes=state.cum_cross_bytes + cross_b,
            feat_sep=new_feat_sep, seed=state.seed)
        out = RoundOut(delivered=delivered, rep=new_rep, cost=cost,
                       intra_bytes=intra_b, cross_bytes=cross_b,
                       params_l2=tree_l2(params), feat_weights=feat_w)
        return new_state, out

    # --- specs: the client axis of data/residuals is sharded over the
    # mesh; params, reputation and edge residuals are replicated
    sharded_res_client = P(AXES) if client_wire_active else P()
    state_specs = RoundState(
        params=jax.tree.map(lambda _: P(), template),
        rep_ema=P(), res_client=sharded_res_client, res_edge=P(),
        cum_cost=P(), cum_intra_bytes=P(), cum_cross_bytes=P(),
        feat_sep=P(), seed=P())
    data_specs = ClientData(client_x=P(AXES), client_y=P(AXES),
                            ref_x=P(), ref_y=P(), malicious=P(AXES))
    out_specs = (state_specs,
                 RoundOut(delivered=P(), rep=P(), cost=P(),
                          intra_bytes=P(), cross_bytes=P(),
                          params_l2=P(), feat_weights=P()))

    def _program(state, data, ts):
        def body(c, t):
            return round_step_local(c, data, t)
        return jax.lax.scan(body, state, ts)

    def _program_step(state, data, t):
        return round_step_local(state, data, t)

    run_jit = jax.jit(_shard_map(
        _program, mesh=mesh,
        in_specs=(state_specs, data_specs, P()), out_specs=out_specs))
    step_jit = jax.jit(_shard_map(
        _program_step, mesh=mesh,
        in_specs=(state_specs, data_specs, P()), out_specs=out_specs))

    def _place(tree, specs):
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            tree, specs)

    def stage_data(data: ClientData) -> ClientData:
        return ClientData(
            client_x=jax.device_put(data.client_x,
                                    NamedSharding(mesh, P(AXES))),
            client_y=jax.device_put(data.client_y,
                                    NamedSharding(mesh, P(AXES))),
            ref_x=jax.device_put(data.ref_x, NamedSharding(mesh, P())),
            ref_y=jax.device_put(data.ref_y, NamedSharding(mesh, P())),
            malicious=jax.device_put(data.malicious,
                                     NamedSharding(mesh, P(AXES))))

    def init_state(seed: int) -> RoundState:
        # the scan engine's round-zero state, plus mesh placement
        state = init_round_state(st, d, seed,
                                 client_wire_active=client_wire_active,
                                 edge_wire_active=edge_wire_active)
        return RoundState(
            params=_place(state.params, state_specs.params),
            rep_ema=jax.device_put(state.rep_ema, NamedSharding(mesh, P())),
            res_client=jax.device_put(
                state.res_client, NamedSharding(mesh, sharded_res_client)),
            res_edge=jax.device_put(state.res_edge,
                                    NamedSharding(mesh, P())),
            cum_cost=jax.device_put(state.cum_cost,
                                    NamedSharding(mesh, P())),
            cum_intra_bytes=jax.device_put(state.cum_intra_bytes,
                                           NamedSharding(mesh, P())),
            cum_cross_bytes=jax.device_put(state.cum_cross_bytes,
                                           NamedSharding(mesh, P())),
            feat_sep=jax.device_put(state.feat_sep,
                                    NamedSharding(mesh, P())),
            seed=jax.device_put(state.seed, NamedSharding(mesh, P())))

    def run(state: RoundState, data: ClientData, rounds: int):
        """scan the sharded engine over ``rounds`` — one device call."""
        return run_jit(state, data, jnp.arange(rounds, dtype=jnp.int32))

    def step(state: RoundState, data: ClientData, t):
        return step_jit(state, data, jnp.asarray(t, jnp.int32))

    return CompiledShard(shard_static=shard_static, mesh=mesh,
                         step=step, run=run, init_state=init_state,
                         stage_data=stage_data, d_params=d, ll_spec=ll,
                         client_payload=client_payload,
                         edge_payload=edge_payload)


def engine_for(flcfg: FLConfig, topo: CloudTopology, data: FederatedData,
               method: str, scenario: Optional[Scenario] = None, *,
               n_devices: Optional[int] = None) -> CompiledShard:
    """Convenience: compile key from (config, data shapes) → engine."""
    ss = static_from_shard(flcfg, topo, method, scenario,
                           input_shape=data.client_x.shape[2:],
                           n_classes=data.n_classes, n_devices=n_devices)
    return compiled_sharded(ss)

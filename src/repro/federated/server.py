"""Server-side orchestration of Algorithm 1 at simulation scale, plus
baseline servers (FedAvg / Krum / Trimmed-Mean / Median / FLTrust) sharing
the same round loop so Table I / Fig. 2-4 comparisons are apples-to-apples.

``FLServer`` is a thin stateful wrapper over the device-resident round
engine (``repro.federated.engine``): when the (method, attack, scenario)
combination is jittable, each ``run_round`` is ONE jitted device call on
a ``RoundState`` pytree; scenarios with host-only hooks (or dropout with
an order-statistic aggregator) transparently fall back to the legacy
host loop below, which remains the reference implementation of the
per-round protocol.
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields
from functools import lru_cache
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.compress import ef_step, policy_from_flcfg
from repro.configs.base import FLConfig
from repro.core import (CloudTopology, CostModel, ReputationState,
                        apply_update_attack, cost_trustfl_aggregate,
                        coordinate_median, fedavg, fltrust, krum,
                        select_clients, trimmed_mean)
from repro.core.selection import exploration_quota, selected_count
from repro.core.fl_types import RoundMetrics
from repro.data.pipeline import FederatedData
from repro.federated import client as client_mod
from repro.federated import engine as engine_mod
from repro.federated.engine import last_layer_spec, ravel_rows, tree_l2
from repro.scenarios.base import Scenario
from repro.telemetry import spans
from repro.telemetry.schema import RunContext

Array = jax.Array

_REF_BATCH = engine_mod.REF_BATCH  # reference LocalTrain batch

# the host loop's RoundState digest: one tiny jitted reduce over the
# params pytree — the same function the device engines run in-graph
_tree_l2_jit = jax.jit(tree_l2)


@lru_cache(maxsize=None)
def _jitted_trainers(epochs: int, batch: int, lr: float
                     ) -> Tuple[Callable, Callable]:
    """Shared jit-of-vmap trainers keyed by the training schedule, so
    every server with the same (epochs, batch, lr) reuses one compiled
    executable per data shape instead of retracing per FLServer — the
    scenario × method test matrix instantiates dozens of servers."""
    train_sel = jax.jit(jax.vmap(
        lambda p, x, y, k: client_mod.local_train(
            p, x, y, k, epochs=epochs, batch=batch, lr=lr),
        in_axes=(None, 0, 0, 0)))
    # reference LocalTrain uses the SAME schedule as clients so the
    # Eq. 12 rescale preserves the effective server step size
    train_refs = jax.jit(jax.vmap(
        lambda p, x, y, k: client_mod.local_train(
            p, x, y, k, epochs=epochs, batch=_REF_BATCH, lr=lr),
        in_axes=(None, 0, 0, None)))
    return train_sel, train_refs


@dataclass
class FLServer:
    """One server object per method; ``method`` picks the aggregation.

    ``engine`` selects the round driver: ``"auto"`` (mesh-sharded engine
    when >1 device is visible and the combination supports it, else the
    single-device scan engine when jittable, else the host loop),
    ``"shard"`` (force the ``("cloud", "client")`` mesh engine; raises
    if unsupported), ``"jit"`` (force the scan engine; raises if
    unsupported), ``"host"`` (force the legacy loop — reference
    semantics, used by the engine benchmark baseline). Routing lives in
    ``engine.resolve_engine``.
    """
    flcfg: FLConfig
    topo: CloudTopology
    data: FederatedData
    method: str = "cost_trustfl"
    seed: int = 0
    # optional adversary/environment scenario (repro.scenarios): its
    # hooks are the ONLY extension points run_round exposes — pricing
    # (round_start), delivery failures (delivered), per-round active
    # malice (active_malicious)
    scenario: Optional[Scenario] = None
    engine: str = "auto"
    # optional telemetry recorder (repro.telemetry.Telemetry or any
    # object with emit(dict)): run_start on construction, a round event
    # per run_round (identical across drivers given identical round
    # outputs), compile/execute spans; run_id defaults to
    # "<method>-s<seed>" so re-runs produce byte-comparable streams
    telemetry: Optional[Any] = None
    run_id: Optional[str] = None

    def __post_init__(self):
        key = jax.random.PRNGKey(self.seed)
        shape = self.data.client_x.shape[2:]
        self.params = client_mod.cnn_init(key, shape, self.data.n_classes)
        self.rep = ReputationState.init(self.topo.n_clients)
        self.cost_model = CostModel(self.flcfg.c_intra, self.flcfg.c_cross)
        # Eq. 10 sees the hierarchical marginal cost (see CostModel);
        # the flat Eq. 2 prices are used for the baselines' accounting
        self.unit_costs = self.cost_model.hierarchical_unit_costs(self.topo)
        self.cum_cost = 0.0
        # ravel machinery cached ONCE: the unravel closure and the flat
        # size are pure functions of the params template, not the round
        flat0, self._unravel = ravel_pytree(self.params)
        self.d_params = int(flat0.size)
        self.malicious = engine_mod.draw_malicious(self.flcfg,
                                                   self.topo.n_clients,
                                                   self.seed)
        # the trust path's g^(L): derived from the template's leaf tail
        # (not a hardcoded fc2_* name), with static flat-slice indices
        self._ll_spec = last_layer_spec(self.params)
        self._ll_idx = jnp.asarray(self._ll_spec.flat_idx)
        self._poisoned_y = engine_mod.poison_labels(
            self.flcfg, self.data, self.malicious, self.seed)
        self.history: List[RoundMetrics] = []
        # per-link gradient compression (repro.compress): codec per link
        # class, lazy error-feedback residual buffers per sender
        self.link_policy = policy_from_flcfg(self.flcfg)
        self._res_client: Optional[Array] = None    # (N, D) client uplinks
        self._res_edge: Optional[Array] = None      # (K, D) edge uplinks
        # multi-feature trust state (trust_features="multi"): the (F,)
        # separability EMA carried across rounds + the last round's
        # softmax mixing weights (telemetry)
        self._feat_sep: Optional[Array] = None
        self._feat_weights: Optional[np.ndarray] = None
        self.cum_intra_bytes = 0.0
        self.cum_cross_bytes = 0.0
        # jit the hot paths ONCE, shared across servers with the same
        # schedule (re-tracing per round — or per server in a scenario
        # matrix — dominates runtime on CPU otherwise)
        fl = self.flcfg
        self._train_selected, self._train_refs = _jitted_trainers(
            fl.local_epochs, fl.local_batch, fl.lr)
        # device engines: compiled programs are shared across servers
        # with the same static config (lru_cache), state/data live on
        # device; the sharded and scan engines duck-type the same
        # step/host_round_accounting surface, so run_round below is
        # driver-agnostic
        self._eng = None
        resolved = engine_mod.resolve_engine(self.engine, fl, self.topo,
                                             self.method, self.scenario)
        if resolved == "shard":
            from repro.federated import sharded as sharded_mod
            self._eng = sharded_mod.engine_for(fl, self.topo, self.data,
                                               self.method, self.scenario)
            self._eng_data = self._eng.stage_data(
                engine_mod.make_client_data(
                    fl, self.topo, self.data, self.seed,
                    malicious=self.malicious, poisoned_y=self._poisoned_y))
            self._eng_state = self._eng.init_state(self.seed)
        elif resolved == "jit":
            static = engine_mod.static_from(
                fl, self.topo, self.method, self.scenario,
                input_shape=shape, n_classes=self.data.n_classes)
            self._eng = engine_mod.compiled(static)
            self._eng_data = engine_mod.make_client_data(
                fl, self.topo, self.data, self.seed,
                malicious=self.malicious, poisoned_y=self._poisoned_y)
            self._eng_state = self._eng.init_state(self.seed)
        self.engine_resolved = resolved
        self._stepped = False                 # first run_round compiles
        self._telemetry_ctx: Optional[RunContext] = None
        if self.telemetry is not None:
            hier = self.method == "cost_trustfl"
            h = engine_mod.hooks_of(self.scenario)
            quota = exploration_quota(fl.cost_lambda) if hier else 0
            m_total = selected_count(self.topo.n_clients,
                                     fl.clients_per_round, quota,
                                     self.topo.cloud_of)
            cp, ep = self._link_payloads(hier)
            self._telemetry_ctx = RunContext(
                self.telemetry, engine=resolved,
                run_id=(self.run_id if self.run_id is not None
                        else f"{self.method}-s{self.seed}"),
                method=self.method, attack=fl.attack, seed=self.seed,
                topo=self.topo, d_params=self.d_params,
                hierarchical=hier, m_selected=m_total,
                malicious=self.malicious, client_payload=cp,
                edge_payload=ep, c_intra=fl.c_intra, c_cross=fl.c_cross,
                price_multipliers=h.price_multipliers,
                malice_warmup=h.malice_warmup,
                scenario=(self.scenario.name if self.scenario is not None
                          else None),
                trust_features=fl.trust_features)
            self._telemetry_ctx.run_start(
                config={f.name: getattr(fl, f.name)
                        for f in fields(fl)})

    # -- selection (host path) -------------------------------------------------
    def _select(self, rng: np.random.Generator) -> np.ndarray:
        m = self.flcfg.clients_per_round
        if self.method == "cost_trustfl":
            quota = exploration_quota(self.flcfg.cost_lambda)
            return select_clients(np.array(self.rep.ema), self.unit_costs, m,
                                  per_cloud_min=quota,
                                  cloud_of=self.topo.cloud_of,
                                  cost_lambda=self.flcfg.cost_lambda, rng=rng)
        sel = np.zeros(self.topo.n_clients, bool)
        sel[rng.choice(self.topo.n_clients, m, replace=False)] = True
        return sel

    # -- reference updates (per-cloud trusted datasets) ------------------------
    def _reference_updates(self, key: Array) -> Any:
        return self._train_refs(self.params, jnp.asarray(self.data.ref_x),
                                jnp.asarray(self.data.ref_y), key)

    # -- per-link compression (repro.compress) ---------------------------------
    def _ef_rows(self, codec, flat_sel: Array, sel_ix: np.ndarray,
                 local_rows: np.ndarray, key: Array) -> Array:
        """Error-feedback round-trip the given rows of the selected-update
        matrix through ``codec``, persisting per-client residuals."""
        if codec.is_identity or local_rows.size == 0:
            return flat_sel
        if self._res_client is None:
            self._res_client = jnp.zeros(
                (self.topo.n_clients, flat_sel.shape[1]), jnp.float32)
        rows = jnp.asarray(sel_ix[local_rows])
        # rows carry their GLOBAL client ids into the codec so stochastic
        # noise is keyed per sender, identically to the device engines
        x_hat, new_res = ef_step(codec, flat_sel[local_rows],
                                 self._res_client[rows], key, rows)
        self._res_client = self._res_client.at[rows].set(new_res)
        return flat_sel.at[jnp.asarray(local_rows)].set(x_hat)

    def _compress_client_uplinks(self, flat_sel: Array, sel_ix: np.ndarray,
                                 key: Array) -> Array:
        """Apply each selected client's uplink codec. Under the hierarchy
        every client→edge hop is intra-cloud; on the flat baseline path a
        client's single hop is intra or cross by co-location."""
        lp = self.link_policy
        local = np.arange(sel_ix.size)
        if self.method == "cost_trustfl":
            return self._ef_rows(lp.intra, flat_sel, sel_ix, local, key)
        same = self.topo.cloud_of[sel_ix] == self.topo.aggregator_cloud
        flat_sel = self._ef_rows(lp.intra, flat_sel, sel_ix, local[same],
                                 jax.random.fold_in(key, 0))
        return self._ef_rows(lp.cross, flat_sel, sel_ix, local[~same],
                             jax.random.fold_in(key, 1))

    def _edge_transform(self, key: Array, sel: np.ndarray
                        ) -> Optional[Callable]:
        """Edge→global wire model for cost_trustfl_aggregate: the shared
        ``engine.build_edge_wire_fn`` EF closure (one source of truth
        across the host loop and both device engines — the key folds are
        part of the cross-engine parity contract), adapted to this
        loop's mutable residual buffer. ``key`` is the already-folded
        ``_FOLD_EDGE_WIRE`` stream. Inactive clouds (no selected
        clients — their aggregate row is the receiver-side reference
        fallback, nothing crosses the wire) pass through untouched and
        keep their residual, matching round_bytes which bills them zero
        bytes."""
        lp = self.link_policy
        if not lp.any_active:
            return None
        wire = engine_mod.build_edge_wire_fn(lp, self.topo.n_clouds,
                                             self.topo.aggregator_cloud)
        active = jnp.asarray(np.bincount(
            self.topo.cloud_of[np.asarray(sel, bool)],
            minlength=self.topo.n_clouds) > 0)[:, None]

        def transform(cloud_aggs: Array) -> Array:
            if self._res_edge is None:
                self._res_edge = jnp.zeros_like(cloud_aggs)
            out, self._res_edge = wire(cloud_aggs, self._res_edge, active,
                                       key)
            return out

        return transform

    def _link_payloads(self, hierarchical: bool
                       ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact wire bytes per client uplink (N,) and edge uplink (K,)."""
        return self.link_policy.payload_vectors(self.topo, self.d_params,
                                                hierarchical=hierarchical)

    # -- one round --------------------------------------------------------------
    def run_round(self, t: int) -> RoundMetrics:
        ctx = self._telemetry_ctx
        if ctx is None:
            if self._eng is not None:
                return self._run_round_engine(t)
            return self._run_round_host(t)
        # span events separate compile (first round traces + compiles
        # the step) from steady-state execute
        phase = "execute" if self._stepped else "compile+execute"
        with spans.span("round", ctx, phase=phase, t=t):
            metrics = (self._run_round_engine(t) if self._eng is not None
                       else self._run_round_host(t))
        self._stepped = True
        return metrics

    def _run_round_engine(self, t: int) -> RoundMetrics:
        """Engine driver: one jitted device call, then host-side float64
        accounting from the delivered mask (byte-exact at any scale and
        bit-identical to the lax.scan driver, which reduces the same
        per-round masks)."""
        state, out = self._eng.step(self._eng_state, self._eng_data, t)
        self._eng_state = state
        self.params = state.params
        self.rep = ReputationState(ema=state.rep_ema)
        delivered = np.asarray(out.delivered)
        cost, intra_b, cross_b = self._eng.host_round_accounting(
            delivered[None], t0=t)[0]
        self.cum_cost += cost
        self.cum_intra_bytes += intra_b
        self.cum_cross_bytes += cross_b
        metrics = RoundMetrics(round=t, cost=cost, cum_cost=self.cum_cost,
                               selected=delivered,
                               reputation=np.array(state.rep_ema),
                               extra={"intra_bytes": intra_b,
                                      "cross_bytes": cross_b})
        if self._telemetry_ctx is not None:
            # same raw inputs and accounting floats as the scan stream
            # collector → byte-identical round events across drivers
            self._telemetry_ctx.round(
                t, delivered, metrics.reputation, float(out.params_l2),
                cost=float(cost), intra_bytes=float(intra_b),
                cross_bytes=float(cross_b),
                feat_weights=(np.asarray(out.feat_weights)
                              if np.asarray(out.feat_weights).size
                              else None))
        self.history.append(metrics)
        return metrics

    def _run_round_host(self, t: int) -> RoundMetrics:
        """Legacy host loop — the reference protocol implementation, and
        the only driver for scenarios with host-only hooks."""
        rng = np.random.default_rng(self.seed * 100003 + t)
        key = jax.random.PRNGKey(self.seed * 7919 + t)
        sc = self.scenario
        if sc is not None:
            # environment mutation (e.g. dynamic egress pricing) BEFORE
            # selection, so Eq. 10 and this round's $ see the same prices
            sc.round_start(self, t, rng)
        sel = self._select(rng)
        if sc is not None:
            # dropout/stragglers: selected clients that never deliver
            # neither train nor put bytes on the wire
            sel = sc.delivered(self, t, rng, sel)
        sel_ix = np.nonzero(sel)[0]

        # local training for selected clients (vmap over clients)
        keys = jax.random.split(key, self.topo.n_clients)
        upd_tree = self._train_selected(
            self.params, jnp.asarray(self.data.client_x[sel_ix]),
            jnp.asarray(self._poisoned_y[sel_ix]), keys[sel_ix])

        flat_sel = ravel_rows(upd_tree)

        # update-level attacks on the round's ACTIVE malicious clients
        # (scenarios may gate the static set, e.g. intermittent sleepers)
        malicious = (self.malicious if sc is None
                     else sc.active_malicious(self, t))
        mal_sel = jnp.asarray(malicious[sel_ix])
        flat_sel = apply_update_attack(
            self.flcfg.attack, flat_sel, mal_sel, key,
            sigma=self.flcfg.gaussian_sigma, scale=self.flcfg.attack_scale,
            z=self.flcfg.attack_z)

        n = self.topo.n_clients
        lp = self.link_policy
        # does any client-uplink codec actually distort flat_sel? (under
        # the hierarchy every client hop is intra; the default cross_only
        # policy leaves them untouched)
        client_wire_active = (not lp.intra.is_identity
                              if self.method == "cost_trustfl"
                              else lp.any_active)
        if client_wire_active:
            # client uplink wire: compress after the (sender-side) attack;
            # everything downstream — trust, Shapley, aggregation — sees
            # only the decompressed updates, incl. the last-layer slice
            flat_sel = self._compress_client_uplinks(
                flat_sel, sel_ix, jax.random.fold_in(key, 211))
        # the trust path's last-layer slice is ALWAYS taken from the
        # attacked (and possibly compressed) flat matrix, so statistics-
        # based adversaries (ALIE / IPM / min-max) present one consistent
        # view to trust scoring and aggregation
        ll_sel = flat_sel[:, self._ll_idx]

        # scatter to full (N, D) with zeros for non-selected
        flat = jnp.zeros((n, flat_sel.shape[1]), flat_sel.dtype
                         ).at[jnp.asarray(sel_ix)].set(flat_sel)
        ll = jnp.zeros((n, ll_sel.shape[1]), ll_sel.dtype
                       ).at[jnp.asarray(sel_ix)].set(ll_sel)

        # aggregate
        update_flat, hierarchical = self._aggregate(flat, ll, key, sel)

        # apply: w <- w - eta * g   (server_lr; g is a model delta)
        delta = self._unravel(update_flat * self.flcfg.server_lr)
        self.params = jax.tree.map(lambda w, g: w - g, self.params, delta)

        # cost accounting (Eq. 1 / Eq. 3 structure) at exact wire bytes
        client_payload, edge_payload = self._link_payloads(hierarchical)
        intra_b, cross_b = self.cost_model.round_bytes(
            self.topo, sel, self.d_params, hierarchical=hierarchical,
            client_payload=client_payload, edge_payload=edge_payload)
        cost = self.cost_model.round_cost(
            self.topo, sel, self.d_params, hierarchical=hierarchical,
            client_payload=client_payload, edge_payload=edge_payload)
        self.cum_cost += cost
        self.cum_intra_bytes += intra_b
        self.cum_cross_bytes += cross_b
        metrics = RoundMetrics(round=t, cost=cost, cum_cost=self.cum_cost,
                               selected=sel,
                               reputation=np.array(self.rep.ema),
                               extra={"intra_bytes": intra_b,
                                      "cross_bytes": cross_b})
        if self._telemetry_ctx is not None:
            # explicit $ /bytes: only this loop knows prices a host hook
            # may have mutated (self.cost_model); digest via the same
            # tree_l2 the device engines run in-graph
            self._telemetry_ctx.round(
                t, sel, metrics.reputation,
                float(_tree_l2_jit(self.params)),
                cost=float(cost), intra_bytes=float(intra_b),
                cross_bytes=float(cross_b),
                feat_weights=self._feat_weights)
        self.history.append(metrics)
        return metrics

    def _aggregate(self, flat: Array, ll: Array, key: Array,
                   sel: np.ndarray) -> Tuple[Array, bool]:
        method = self.method
        sel_j = jnp.asarray(sel)
        if method == "cost_trustfl":
            ref_tree = self._reference_updates(key)
            ref_flat = ravel_rows(ref_tree)
            ref_ll = ref_flat[:, self._ll_idx]
            res = cost_trustfl_aggregate(
                flat, ll, ref_flat, ref_ll,
                jnp.asarray(self.topo.cloud_of), sel_j, self.rep,
                gamma=self.flcfg.ema_gamma,
                cloud_transform=self._edge_transform(
                    jax.random.fold_in(key, 223), sel),
                trust_features=self.flcfg.trust_features,
                feat_sep=self._feat_sep)
            self.rep = res.reputation
            if res.feat_sep is not None:
                self._feat_sep = res.feat_sep
                self._feat_weights = np.asarray(res.feat_weights)
            return res.update, True
        sel_ix = jnp.nonzero(sel_j, size=int(sel.sum()))[0]
        u = flat[sel_ix]
        if method == "fedavg":
            return fedavg(u), False
        if method == "krum":
            f = int(self.flcfg.malicious_frac * u.shape[0])
            return krum(u, f, multi=max(1, u.shape[0] - f - 2)), False
        if method == "trimmed_mean":
            return trimmed_mean(u, trim_frac=self.flcfg.malicious_frac / 2), False
        if method == "median":
            return coordinate_median(u), False
        if method == "fltrust":
            ref_tree = self._reference_updates(key)
            ref_flat = ravel_rows(ref_tree)
            return fltrust(u, jnp.mean(ref_flat, axis=0)), False
        raise ValueError(method)

    # -- eval -------------------------------------------------------------------
    def evaluate(self) -> float:
        return client_mod.accuracy(self.params,
                                   jnp.asarray(self.data.test_x),
                                   jnp.asarray(self.data.test_y))

    # -- telemetry hooks (no-ops when no recorder is attached) ------------------
    def record_eval(self, t: int, accuracy: float,
                    loss: Optional[float] = None) -> None:
        if self._telemetry_ctx is not None:
            self._telemetry_ctx.eval(t, accuracy, loss)

    def finish_telemetry(self) -> None:
        if self._telemetry_ctx is not None:
            self._telemetry_ctx.run_end()

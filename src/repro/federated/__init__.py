from repro.federated.client import (accuracy, cnn_apply, cnn_init,
                                    local_train, xent_loss)
from repro.federated.server import FLServer
from repro.federated.simulation import (SimResult, compare_methods,
                                        make_data, make_topology,
                                        run_simulation,
                                        run_simulation_batch,
                                        run_simulation_sharded)

__all__ = ["accuracy", "cnn_apply", "cnn_init", "local_train", "xent_loss",
           "FLServer", "SimResult", "compare_methods", "make_data",
           "make_topology", "run_simulation", "run_simulation_batch",
           "run_simulation_sharded"]

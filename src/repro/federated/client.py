"""Client-side substrate for the simulation-scale reproduction:
the paper's CNN (2 conv + 2 FC, §V-A) and vmap-able local training
(LocalTrain in Algorithm 1, line 8)."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Dict[str, Array]


def cnn_init(key: Array, input_shape: Tuple[int, int, int],
             n_classes: int) -> Params:
    h, w, c = input_shape
    ks = jax.random.split(key, 4)
    hh, ww = h // 4, w // 4                      # two 2x2 pools
    flat = hh * ww * 64

    def norm(k, shape, fan_in):
        return jax.random.normal(k, shape) * jnp.sqrt(2.0 / fan_in)

    return {
        "conv1_w": norm(ks[0], (3, 3, c, 32), 9 * c),
        "conv1_b": jnp.zeros((32,)),
        "conv2_w": norm(ks[1], (3, 3, 32, 64), 9 * 32),
        "conv2_b": jnp.zeros((64,)),
        "fc1_w": norm(ks[2], (flat, 128), flat),
        "fc1_b": jnp.zeros((128,)),
        "fc2_w": norm(ks[3], (128, n_classes), 128),
        "fc2_b": jnp.zeros((n_classes,)),
    }


def cnn_apply(params: Params, x: Array) -> Array:
    """x: (B, H, W, C) -> logits (B, n_classes)."""
    def conv(x, w, b):
        # im2col + GEMM: identical math to a SAME 3x3 conv, but lowers to
        # a fast matmul (XLA-CPU's direct conv path is ~50x slower)
        bsz, h, ww, c = x.shape
        xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
        patches = jnp.concatenate(
            [xp[:, i:i + h, j:j + ww, :] for i in range(3)
             for j in range(3)], axis=-1)                 # (B,H,W,9C)
        y = patches @ w.reshape(9 * c, -1)
        return jax.nn.relu(y + b)

    def pool(x):
        # reshape-based 2x2 max-pool (XLA-CPU reduce_window is ~100x
        # slower; this lowers to fast vectorized code on CPU and TPU)
        b, h, w, c = x.shape
        return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))

    x = pool(conv(x, params["conv1_w"], params["conv1_b"]))
    x = pool(conv(x, params["conv2_w"], params["conv2_b"]))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1_w"] + params["fc1_b"])
    return x @ params["fc2_w"] + params["fc2_b"]


def xent_loss(params: Params, x: Array, y: Array) -> Array:
    logits = cnn_apply(params, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def accuracy(params: Params, x: Array, y: Array, batch: int = 512) -> float:
    correct = 0
    for i in range(0, x.shape[0], batch):
        logits = cnn_apply(params, x[i:i + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i:i + batch]))
    return correct / x.shape[0]


@partial(jax.jit, static_argnames=("epochs", "batch"))
def local_train(params: Params, x: Array, y: Array, key: Array, *,
                epochs: int, batch: int, lr: float) -> Params:
    """E epochs of minibatch SGD from the broadcast global params.
    Returns the *update* g_i = w_global - w_local (so that
    w <- w - eta * g descends toward the client optimum).
    vmap-able over a leading client axis."""
    n = x.shape[0]
    steps_per_epoch = max(1, n // batch)
    total = epochs * steps_per_epoch

    def step(p, k):
        ix = jax.random.randint(k, (batch,), 0, n)
        g = jax.grad(xent_loss)(p, x[ix], y[ix])
        p = jax.tree.map(lambda w, gw: w - lr * gw, p, g)
        return p, None

    local, _ = jax.lax.scan(step, params, jax.random.split(key, total))
    return jax.tree.map(lambda g0, g1: g0 - g1, params, local)

"""Serving path: batched one-token decode (``serve_step``) with sharded
KV caches, plus a prefill step. Decode shapes in the dry-run lower these.

Cache sharding (DESIGN.md §5): batch over the data axes when divisible
(decode_32k: 128 sequences / 16 groups); for batch-1 long-context
(long_500k) the cache *sequence* dim shards over ``data`` instead and the
partial-softmax combine is inserted by GSPMD (distributed-cache decode).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.models import transformer as tfm
from repro.sharding.specs import (batch_specs, cache_specs, data_axes,
                                  param_specs)

Array = jax.Array


def make_serve_step(model: Model, mesh: Optional[Mesh], *, batch: int,
                    max_len: int, cache_dtype=jnp.bfloat16,
                    sample: bool = False):
    """Returns ``(serve_step, shardings)`` where
    ``serve_step(params, cache, token, index[, key]) -> (next_token_logits,
    new_cache)`` is jitted with explicit in/out shardings when a mesh is
    given."""
    cfg = model.cfg

    def serve_step(params, cache, token, index):
        logits, new_cache = tfm.decode_step(params, cfg, cache, token, index)
        return logits, new_cache

    if mesh is None:
        return jax.jit(serve_step, donate_argnums=(1,)), None

    pspecs = param_specs(jax.eval_shape(
        lambda k: model.init(k), jax.random.PRNGKey(0)), cfg, mesh)
    cache_shape = jax.eval_shape(
        lambda p: tfm.init_cache(p, cfg, batch, max_len, cache_dtype),
        jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0)))
    cspecs = cache_specs(cache_shape, cfg, mesh, batch)
    tok_spec = batch_specs(cfg, mesh, batch)

    shardings = {
        "params": jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
        "cache": jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs),
        "token": NamedSharding(mesh, tok_spec),
    }
    jitted = jax.jit(
        serve_step,
        in_shardings=(shardings["params"], shardings["cache"],
                      shardings["token"], None),
        out_shardings=(NamedSharding(mesh, tok_spec), shardings["cache"]),
        donate_argnums=(1,),
    )
    return jitted, shardings


def make_prefill_step(model: Model, mesh: Optional[Mesh], *, batch: int):
    """Full-sequence forward producing last-position logits (the
    prefill_32k dry-run shape)."""
    cfg = model.cfg

    def prefill(params, batch_inputs):
        h, _, off = tfm.forward_hidden(params, cfg, batch_inputs)
        logits = tfm.logits_fn(params, cfg, h[:, -1:])[:, 0]
        from repro.models.common import softcap
        return softcap(logits, cfg.logit_softcap)

    if mesh is None:
        return jax.jit(prefill)
    pspecs = param_specs(jax.eval_shape(
        lambda k: model.init(k), jax.random.PRNGKey(0)), cfg, mesh)
    return jax.jit(
        prefill,
        in_shardings=(jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
                      None))


def greedy_generate(model: Model, params, prompt: Array, steps: int,
                    max_len: int) -> Array:
    """Small-scale CPU generation helper for examples/tests."""
    b, s = prompt.shape
    _, cache = model.prefill(params, {"tokens": prompt}, max_len)
    tok = jnp.argmax(jax.nn.one_hot(prompt[:, -1], model.cfg.vocab_size), -1)
    out = [prompt]
    step_fn = jax.jit(lambda p, c, t, i: tfm.decode_step(p, model.cfg, c, t, i))
    for i in range(steps):
        logits, cache = step_fn(params, cache, tok, jnp.asarray(s + i))
        tok = jnp.argmax(logits, axis=-1)
        out.append(tok[:, None])
    return jnp.concatenate(out, axis=1)

"""Roofline analysis from compiled dry-run artifacts (no TPU required).

Terms (per chip, seconds):
  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = Σ per-device collective payload x type-multiplier / ICI_BW

Collective bytes are parsed from the partitioned HLO text (SPMD: shapes
are per-device shards; every device executes each collective once).
Type multipliers approximate ring algorithms: all-reduce moves ~2x its
payload per device, all-gather/reduce-scatter ~1x, all-to-all ~1x,
collective-permute 1x. Ops whose replica_groups span pods are counted as
cross-pod (DCI) traffic and priced at the paper's egress rate
($0.09/GB, Eq. 2) — the TPU mapping of cross-cloud cost.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link
EGRESS_PER_GB = 0.09      # $ (AWS egress, paper §I)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "u1": 1, "s1": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[([0-9,]+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{([^}]*(?:\},\{[^}]*)*)\}")

_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveOp:
    kind: str
    bytes: int
    cross_pod: bool


@dataclass
class RooflineReport:
    arch: str = ""
    shape: str = ""
    mesh: str = ""
    chips: int = 0
    flops_per_device: float = 0.0
    bytes_per_device: float = 0.0
    collective_bytes_per_device: float = 0.0
    cross_pod_bytes_per_device: float = 0.0
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0
    useful_flops_ratio: float = 0.0
    egress_dollars_per_step: float = 0.0
    n_collectives: int = 0
    collectives_by_kind: Dict[str, int] = field(default_factory=dict)
    memory_per_device_bytes: Dict[str, float] = field(default_factory=dict)

    def to_json(self) -> dict:
        return asdict(self)


def _parse_groups_cross_pod(line: str, pod_of: Optional[np.ndarray]) -> bool:
    """True if any replica group (or permute pair) spans >1 pod."""
    if pod_of is None:
        return False
    m = _GROUPS_RE.search(line)
    if m:
        for grp in re.findall(r"\{([0-9, ]+)\}", m.group(0)):
            ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if len({int(pod_of[i]) for i in ids if i < len(pod_of)}) > 1:
                return True
        return False
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota tile notation e.g. [16,32]<=[32,16]T(1,0) — decode by
        # materializing the permutation
        try:
            out_shape = [int(x) for x in m.group(1).split(",")]
            in_shape = [int(x) for x in m.group(2).split(",")]
            ids = np.arange(int(np.prod(in_shape))).reshape(in_shape)
            if m.group(3):
                perm = [int(x) for x in m.group(3).split(",")]
                ids = ids.transpose(perm)
            groups = ids.reshape(out_shape)
            for row in groups:
                if len({int(pod_of[i]) for i in np.ravel(row)}) > 1:
                    return True
            return False
        except Exception:
            return True  # conservative
    m = _PAIRS_RE.search(line)
    if m:
        for pair in re.findall(r"\{([0-9, ]+)\}", "{" + m.group(1) + "}"):
            ids = [int(x) for x in pair.replace(" ", "").split(",") if x]
            if len(ids) == 2 and pod_of[ids[0]] != pod_of[ids[1]]:
                return True
    return False


def parse_collectives(hlo_text: str, pod_of: Optional[np.ndarray] = None
                      ) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    seen_starts = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        # avoid double counting start/done pairs: count only non-done
        if "-done(" in line:
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        if b == 0:
            continue
        ops.append(CollectiveOp(kind=kind, bytes=b,
                                cross_pod=_parse_groups_cross_pod(line,
                                                                  pod_of)))
    return ops


def pod_map(mesh) -> Optional[np.ndarray]:
    """device-id -> pod index (None for single-pod meshes)."""
    if "pod" not in mesh.axis_names:
        return None
    pod_axis = list(mesh.axis_names).index("pod")
    ids = np.vectorize(lambda d: d.id)(mesh.devices)
    pod_of = np.zeros(ids.size, np.int32)
    for pod in range(mesh.devices.shape[pod_axis]):
        sl = [slice(None)] * mesh.devices.ndim
        sl[pod_axis] = pod
        pod_of[ids[tuple(sl)].ravel()] = pod
    return pod_of


def analyze(compiled, mesh, *, arch: str = "", shape: str = "",
            model_flops: float = 0.0) -> RooflineReport:
    chips = int(np.prod(list(mesh.shape.values())))
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    ops = parse_collectives(hlo, pod_map(mesh))
    coll = sum(op.bytes * _MULT[op.kind] for op in ops)
    cross = sum(op.bytes for op in ops if op.cross_pod)
    by_kind: Dict[str, int] = {}
    for op in ops:
        by_kind[op.kind] = by_kind.get(op.kind, 0) + 1

    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = coll / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    # global egress: each device in the smaller half of a cross-pod group
    # pushes its payload over the DCI once per op
    egress_bytes_global = cross * chips / 2
    egress = egress_bytes_global / (1024 ** 3) * EGRESS_PER_GB

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem[k] = float(getattr(ma, k, 0.0))
    except Exception:
        pass

    useful = model_flops / (flops * chips) if flops else 0.0
    return RooflineReport(
        arch=arch, shape=shape,
        mesh="x".join(f"{k}{v}" for k, v in mesh.shape.items()),
        chips=chips, flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=coll, cross_pod_bytes_per_device=cross,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops,
        useful_flops_ratio=useful, egress_dollars_per_step=egress,
        n_collectives=len(ops), collectives_by_kind=by_kind,
        memory_per_device_bytes=mem)

from repro.train.steps import (MeshTopology, make_fl_train_step,
                               make_fused_step, make_plain_step,
                               make_two_phase_step)

__all__ = ["MeshTopology", "make_fl_train_step", "make_fused_step",
           "make_plain_step", "make_two_phase_step"]

"""Distributed Cost-TrustFL train steps (the paper's Algorithm 1 as a
single jitted SPMD step on the production mesh).

Client/cloud mapping (DESIGN.md §2): clients = data-axis shard groups,
clouds = pods (multi-pod mesh) or contiguous groups of the data axis
(single-pod mesh). Two strategies:

* ``two_phase`` (paper-faithful): ``jax.shard_map`` manual over the data
  axes with the ``model`` axis left to GSPMD (auto). Each shard group
  computes its client's full gradient, Eq. 7–13 run exactly (true
  last-layer gradients, true full-gradient norms), hierarchical weighted
  psums implement Eq. 5–6.

* ``fused`` (beyond-paper): pure GSPMD. Per-client *signatures*
  (final-norm-scale gradient + random-projection sketch of the lm-head
  gradient) are computed from one forward pass; trust weights derived
  from signatures; then ONE backward of the trust-weighted loss yields
  the aggregated update directly. Compatible with FSDP param sharding
  (required for the >=47B architectures).
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import FLConfig, ModelConfig
from repro.core.selection import select_clients_jax
from repro.core.trust import tree_dot, tree_norm, tree_scale
from repro.models.common import softcap
from repro.models.model import Model
from repro.models import transformer as tfm
from repro.sharding.specs import (data_axes, opt_state_specs, param_specs,
                                  tree_batch_specs)

Array = jax.Array


# ---------------------------------------------------------------------------
# topology helpers

@dataclass(frozen=True)
class MeshTopology:
    """Client/cloud layout derived from the mesh (DESIGN.md §2)."""
    daxes: Tuple[str, ...]        # manual client axes, e.g. ('pod','data')
    n_clients: int
    n_clouds: int
    clients_per_cloud: int
    pod_aligned: bool             # clouds == pods?

    @staticmethod
    def from_mesh(mesh: Mesh, n_clouds: Optional[int] = None
                  ) -> "MeshTopology":
        daxes = data_axes(mesh)
        sizes = [mesh.shape[a] for a in daxes]
        n_clients = int(np.prod(sizes)) if sizes else 1
        if "pod" in mesh.axis_names:
            k = mesh.shape["pod"]
            pod_aligned = True
        else:
            k = n_clouds or min(4, n_clients)
            while n_clients % k:
                k -= 1
            pod_aligned = False
        return MeshTopology(tuple(daxes), n_clients, k, n_clients // k,
                            pod_aligned)

    def cloud_of(self) -> np.ndarray:
        return np.arange(self.n_clients) // self.clients_per_cloud

    def unit_costs(self, c_intra: float, c_cross: float,
                   aggregator_cloud: int = 0) -> np.ndarray:
        """Marginal c_i (Eq. 10) under hierarchical aggregation: intra
        upload to the edge + the cloud's single cross-pod upload amortized
        over its cohorts (see CostModel.hierarchical_unit_costs)."""
        cloud = self.cloud_of()
        edge = np.where(cloud == aggregator_cloud, c_intra, c_cross)
        return c_intra + edge / max(self.clients_per_cloud, 1)


def _cloud_groups(topo: MeshTopology):
    """axis_index_groups for intra-cloud psum on the data axis (only used
    when clouds are virtual subdivisions of a single-pod data axis)."""
    return [list(range(k * topo.clients_per_cloud,
                       (k + 1) * topo.clients_per_cloud))
            for k in range(topo.n_clouds)]


# ---------------------------------------------------------------------------
# shared scoring math

def _last_layer(grads: Dict[str, Any], cfg: ModelConfig) -> Dict[str, Any]:
    """The paper's g^(L): last FC (lm-head / tied embedding) + final norm."""
    out = {"final_norm": grads["final_norm"]}
    out["head"] = grads["lm_head"] if "lm_head" in grads else grads["embed"]
    return out


def _phi(ll: Any, ll_bar: Any, eps: float = 1e-12) -> Array:
    """Eq. 7 on pytrees."""
    dot = tree_dot(ll, ll_bar)
    n_i, n_bar = tree_norm(ll), tree_norm(ll_bar)
    cos = dot / jnp.maximum(n_i * n_bar, eps)
    return jax.nn.relu(cos) * n_i


def _shard_map(f, *, mesh, in_specs, out_specs, axis_names):
    """jax.shard_map across jax versions. >=0.6 exposes it at top level and
    keeps the model axis auto (GSPMD) via ``axis_names``. On 0.4.x the
    equivalent would be ``jax.experimental.shard_map(..., auto=<complement
    of axis_names>)``, but partial-auto shard_map CHECK-crashes the XLA CPU
    SPMD partitioner of jaxlib 0.4.36 ("IsManualSubgroup" check), so we run
    fully manual there instead: numerics are identical, the model axis just
    computes replicated work (acceptable for the CPU smoke/dry-run scale
    this fallback serves)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as legacy_shard_map
    return legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# two_phase strategy (paper-faithful, shard_map)

def make_two_phase_step(model: Model, mesh: Mesh, flcfg: FLConfig,
                        optimizer, *, loss_chunk: int = 512
                        ) -> Callable:
    """Returns jitted ``step(params, opt_state, rep, batch, ref_batch)``.

    ``batch``: leaves with leading dim = global_batch, sharded over the
    data axes; each client cohort sees global_batch / n_clients examples.
    ``ref_batch``: leaves with leading dim n_clouds (replicated) — the
    per-cloud trusted reference data (paper §IV-D).
    """
    cfg = model.cfg
    topo = MeshTopology.from_mesh(mesh, flcfg.n_clouds)
    unit_costs = jnp.asarray(topo.unit_costs(flcfg.c_intra, flcfg.c_cross),
                             jnp.float32)
    m_select = min(flcfg.clients_per_round, topo.n_clients)
    _, opt_update = optimizer
    eps = 1e-12

    # NOTE: psums always run in f32 — better reduction numerics, and bf16
    # psum inside shard_map CHECK-crashes the XLA CPU backend used by the
    # dry-run ("Invalid binary instruction opcode copy").
    def intra_psum(x):
        x = x.astype(jnp.float32)
        if topo.pod_aligned:
            return jax.lax.psum(x, "data")
        return jax.lax.psum(x, "data", axis_index_groups=_cloud_groups(topo))

    def cross_sum(x):
        """Sum of one representative value per cloud (values are uniform
        within a cloud after intra_psum)."""
        x = x.astype(jnp.float32)
        if topo.pod_aligned:
            return jax.lax.psum(x, "pod")
        return jax.lax.psum(x, "data") / topo.clients_per_cloud

    def all_sum(x):
        return jax.lax.psum(x.astype(jnp.float32), topo.daxes)

    def client_index():
        if len(topo.daxes) == 2:
            return (jax.lax.axis_index(topo.daxes[0])
                    * jax.lax.axis_size(topo.daxes[1])
                    + jax.lax.axis_index(topo.daxes[1]))
        return jax.lax.axis_index(topo.daxes[0])

    def per_group(params, rep, batch, ref_batch):
        idx = client_index()
        cloud = idx // topo.clients_per_cloud

        loss_of = lambda p, b: model.loss(p, b, loss_chunk)[0]
        # line 8: LocalTrain -> client gradient (one local step; the
        # simulation substrate runs multi-epoch SGD, the production step
        # uses the gradient form of Alg. 1)
        loss_i, g_i = jax.value_and_grad(loss_of)(params, batch)
        # line 10: per-cloud reference gradient on the trusted set
        ref_b = jax.tree.map(lambda x: x[cloud], ref_batch)
        g_ref = jax.grad(loss_of)(params, ref_b)

        # --- Eq. 7–9: reputation from last-layer gradients
        ll_i = _last_layer(g_i, cfg)
        ll_ref = _last_layer(g_ref, cfg)
        ll_bar = jax.tree.map(lambda x: all_sum(x) / topo.n_clients, ll_i)
        phi_i = _phi(ll_i, ll_bar)
        onehot = jax.nn.one_hot(idx, topo.n_clients, dtype=jnp.float32)

        # --- Eq. 10: cost-aware selection from last round's reputation
        sel_mask = select_clients_jax(rep, unit_costs, m_select,
                                      flcfg.cost_lambda)
        sel_i = sel_mask[idx].astype(jnp.float32)

        phi_i = phi_i * sel_i
        phi_sum = all_sum(phi_i)
        r_i = jnp.where(phi_sum > eps, phi_i / jnp.maximum(phi_sum, eps),
                        1.0 / topo.n_clients)
        r_vec = all_sum(onehot * r_i)
        new_rep = jnp.where(sel_mask,
                            flcfg.ema_gamma * rep
                            + (1 - flcfg.ema_gamma) * r_vec, rep)

        # --- Eq. 11: trust score vs own-cloud reference
        cos_ref = tree_dot(ll_i, ll_ref) / jnp.maximum(
            tree_norm(ll_i) * tree_norm(ll_ref), eps)
        ts_i = jax.nn.relu(cos_ref) * new_rep[idx] * sel_i

        # --- Eq. 12: normalize to reference gradient magnitude
        gn_i = tree_norm(g_i)
        gn_ref = tree_norm(g_ref)
        rescale = gn_ref / jnp.maximum(gn_i, eps)

        # --- Eq. 5 + 13 intra-cloud combine, computed PER LEAF so only
        # one leaf's f32 temporaries are live at a time (whole-tree
        # staging kept ~5 full f32 gradient copies resident — §Perf)
        ts_cloud = intra_psum(ts_i)

        def leaf_cloud(gi, gr):
            gc = intra_psum(gi.astype(jnp.float32) * (rescale * ts_i)) \
                / jnp.maximum(ts_cloud, eps)
            return jnp.where(ts_cloud > eps, gc, gr.astype(jnp.float32))

        g_cloud = jax.tree.map(leaf_cloud, g_i, g_ref)

        # --- Eq. 6: cross-cloud combine with cloud trust beta_k
        ll_cloud = _last_layer(g_cloud, cfg)
        ll_gref = jax.tree.map(lambda x: cross_sum(x) / topo.n_clouds,
                               ll_ref)
        beta_k = jax.nn.relu(tree_dot(ll_cloud, ll_gref) / jnp.maximum(
            tree_norm(ll_cloud) * tree_norm(ll_gref), eps))
        beta_sum = cross_sum(beta_k)
        beta_n = jnp.where(beta_sum > eps, beta_k / jnp.maximum(beta_sum, eps),
                           1.0 / topo.n_clouds)
        g_global = jax.tree.map(lambda x: cross_sum(x * beta_n), g_cloud)

        metrics = {
            "loss": all_sum(loss_i * sel_i) / jnp.maximum(all_sum(sel_i), 1.0),
            "phi": all_sum(onehot * phi_i),
            "trust": all_sum(onehot * ts_i),
            "beta": beta_n,
            "selected": sel_mask.astype(jnp.float32),
            "round_cost_units": jnp.sum(sel_mask * unit_costs),
        }
        return g_global, new_rep, metrics

    params_shape = jax.eval_shape(lambda k: model.init(k),
                                  jax.random.PRNGKey(0))
    pspecs = param_specs(params_shape, cfg, mesh)
    dax = topo.daxes if len(topo.daxes) > 1 else topo.daxes[0]

    def step(params, opt_state, rep, batch, ref_batch):
        mapped = _shard_map(
            per_group, mesh=mesh,
            in_specs=(P(), P(), P(dax), P()),
            out_specs=(P(), P(), P()),
            axis_names=set(topo.daxes),
        )
        g_global, new_rep, metrics = mapped(params, rep, batch, ref_batch)
        # optimizer update at GSPMD level: ZeRO-1 — moments are sharded
        # over the data axes (opt_state_specs); g_global is replicated
        new_params, new_opt = opt_update(g_global, opt_state, params)
        return new_params, new_opt, new_rep, metrics

    opt_shape = jax.eval_shape(optimizer[0], params_shape)
    ospecs = opt_state_specs(opt_shape, params_shape, cfg, mesh)
    donate = () if os.environ.get("REPRO_NO_DONATE") else (0, 1)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    o_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs)
    return jax.jit(
        step,
        in_shardings=(p_sh, o_sh, None, None, None),
        # pin outputs so step(step(...)) round-trips without resharding
        out_shardings=(p_sh, o_sh, None, None),
        donate_argnums=donate,
    ), topo


# ---------------------------------------------------------------------------
# fused strategy (beyond-paper, pure GSPMD + signatures)

def _signatures(params, cfg: ModelConfig, batch, n_clients: int,
                sketch_dim: int, key: Array, loss_chunk: int = 512
                ) -> Tuple[Array, Array, Array]:
    """One forward pass -> per-client (loss, signature, signature-norm).

    signature_i = [ vec(Σ_t h_t ⊗ ((p_t − y_t) Ω)) ;  dL/dγ_final ]
    where Ω is a fixed (vocab, sketch) Rademacher projection — a JL sketch
    of the true lm-head gradient Σ_t h_t ⊗ (p_t − y_t).
    Shapes: losses (N,), signatures (N, D·s + D).
    """
    from repro.sharding.constrain import constrain
    h, aux, off = tfm.forward_hidden(params, cfg, batch)
    h = h[:, off:]
    tokens, labels = batch["tokens"], batch["labels"]
    mask = batch["mask"].astype(jnp.float32)
    b, s, d = h.shape
    per = b // n_clients

    # client-major layout: the client dim (N) aligns with the mesh data
    # axes exactly like the two_phase strategy's shard groups, so all
    # per-client reductions stay local (no cross-client collectives)
    def cm(x):
        return constrain(x.reshape((n_clients, per) + x.shape[1:]),
                         {0: ("pod", "data")})
    h = cm(h)                                          # (N, per, S, D)
    labels_c, mask_c = cm(labels), cm(mask)

    omega = (2.0 * jax.random.bernoulli(
        key, 0.5, (cfg.vocab_size, sketch_dim)).astype(jnp.float32) - 1.0
             ) / math.sqrt(sketch_dim)

    chunk = min(loss_chunk, s)
    n_chunks = max(1, s // chunk)
    s_trunc = n_chunks * chunk

    def body(carry, xs):
        losses, sk = carry
        hc, yc, mc = xs              # (N,per,c,D),(N,per,c),(N,per,c)
        logits = tfm.logits_fn(params, cfg, hc)
        logits = constrain(logits, {0: ("pod", "data"), 3: "model"})
        logits = softcap(logits, cfg.logit_softcap).astype(jnp.float32)
        p = jax.nn.softmax(logits, axis=-1)
        gold = jax.nn.one_hot(yc, cfg.vocab_size, dtype=jnp.float32)
        dl = constrain((p - gold) * mc[..., None],
                       {0: ("pod", "data"), 3: "model"})  # (N,per,c,V)
        nll = (jax.nn.logsumexp(logits, -1)
               - jnp.take_along_axis(logits, yc[..., None], -1)[..., 0]) * mc
        losses = losses + jnp.sum(nll, axis=(1, 2))
        z = constrain(dl @ omega, {0: ("pod", "data")})   # (N,per,c,s̃)
        sk_c = jnp.einsum("nptd,npts->nds", hc, z)
        return (losses, sk + sk_c), None

    hs = h[:, :, :s_trunc].reshape(n_clients, per, n_chunks, chunk, d)
    ys = labels_c[:, :, :s_trunc].reshape(n_clients, per, n_chunks, chunk)
    ms = mask_c[:, :, :s_trunc].reshape(n_clients, per, n_chunks, chunk)
    init = (jnp.zeros((n_clients,), jnp.float32),
            jnp.zeros((n_clients, d, sketch_dim), jnp.float32))
    (losses, sk), _ = jax.lax.scan(
        body, init, (jnp.moveaxis(hs, 2, 0), jnp.moveaxis(ys, 2, 0),
                     jnp.moveaxis(ms, 2, 0)))

    tok_per_client = jnp.sum(mask_c, axis=(1, 2))
    losses = losses / jnp.maximum(tok_per_client, 1.0)
    sigs = sk.reshape(n_clients, -1) / jnp.maximum(tok_per_client, 1.0
                                                   )[:, None]
    return losses, sigs, jnp.linalg.norm(sigs, axis=1)


def make_fused_step(model: Model, mesh: Mesh, flcfg: FLConfig, optimizer,
                    *, loss_chunk: int = 512) -> Callable:
    """Signature-fused Cost-TrustFL: GSPMD-only, FSDP-compatible."""
    cfg = model.cfg
    topo = MeshTopology.from_mesh(mesh, flcfg.n_clouds)
    unit_costs = jnp.asarray(topo.unit_costs(flcfg.c_intra, flcfg.c_cross),
                             jnp.float32)
    m_select = min(flcfg.clients_per_round, topo.n_clients)
    _, opt_update = optimizer
    cloud_of = jnp.asarray(topo.cloud_of())
    k_clouds = topo.n_clouds
    eps = 1e-12

    def step(params, opt_state, rep, batch, ref_batch, key):
        n = topo.n_clients
        # --- per-client signatures from ONE forward pass
        if os.environ.get("REPRO_FUSED_NOSIG"):       # debug isolation
            losses = jnp.ones((n,), jnp.float32)
            sigs = jnp.ones((n, 8), jnp.float32)
            signorm = jnp.linalg.norm(sigs, axis=1)
        else:
            losses, sigs, signorm = _signatures(params, cfg, batch, n,
                                                flcfg.sketch_dim, key,
                                                loss_chunk)
        # per-cloud reference signatures (tiny forward per cloud)
        if os.environ.get("REPRO_FUSED_NOSIG"):
            ref_sigs_all = jnp.ones((k_clouds, sigs.shape[1]), jnp.float32)
            ref_norms_all = jnp.linalg.norm(ref_sigs_all, axis=1)
        else:
            ref_flat = jax.tree.map(
                lambda x: x.reshape((-1,) + x.shape[2:]), ref_batch)
            _, ref_sigs_all, ref_norms_all = _signatures(
                params, cfg, ref_flat, k_clouds, flcfg.sketch_dim, key,
                loss_chunk)

        # --- Eq. 7–9 on signatures
        sig_bar = jnp.mean(sigs, axis=0)
        cos_bar = (sigs @ sig_bar) / jnp.maximum(
            signorm * jnp.linalg.norm(sig_bar), eps)
        sel_mask = select_clients_jax(rep, unit_costs, m_select,
                                      flcfg.cost_lambda)
        sel = sel_mask.astype(jnp.float32)
        phi = jax.nn.relu(cos_bar) * signorm * sel
        r = jnp.where(jnp.sum(phi) > eps, phi / jnp.maximum(jnp.sum(phi), eps),
                      1.0 / n)
        new_rep = jnp.where(sel_mask, flcfg.ema_gamma * rep
                            + (1 - flcfg.ema_gamma) * r, rep)

        # --- Eq. 11 vs own-cloud reference signature
        ref_sig = ref_sigs_all[cloud_of]                     # (N, Ds)
        cos_ref = jnp.sum(sigs * ref_sig, axis=1) / jnp.maximum(
            signorm * jnp.linalg.norm(ref_sig, axis=1), eps)
        ts = jax.nn.relu(cos_ref) * new_rep * sel
        # degenerate round (every cosine <= 0, e.g. uninformative sketches):
        # fall back to reputation-weighted FedAvg over the selected clients
        # rather than emitting a zero update — mirrors the zero-trust-cloud
        # fallback in cost_trustfl_aggregate
        ts = jnp.where(jnp.sum(ts) > eps, ts, new_rep * sel)

        # --- Eq. 12 proxy: signature-norm normalization
        ref_norm = ref_norms_all[cloud_of]
        scale_i = ref_norm / jnp.maximum(signorm, eps)

        # --- Eq. 5/13 weights + Eq. 6 beta, all in weight space
        cloud_onehot = jax.nn.one_hot(cloud_of, k_clouds,
                                      dtype=jnp.float32)     # (N, K)
        ts_cloud = cloud_onehot.T @ ts                        # (K,)
        # cloud aggregate signature direction for beta
        agg_sig = cloud_onehot.T @ (sigs * (ts * scale_i)[:, None])
        agg_sig = agg_sig / jnp.maximum(ts_cloud, eps)[:, None]
        gref_sig = jnp.mean(ref_sigs_all, axis=0)
        beta = jax.nn.relu(
            (agg_sig @ gref_sig) / jnp.maximum(
                jnp.linalg.norm(agg_sig, axis=1)
                * jnp.linalg.norm(gref_sig), eps))
        beta = jnp.where(jnp.sum(beta) > eps,
                         beta / jnp.maximum(jnp.sum(beta), eps),
                         1.0 / k_clouds)

        w = (beta[cloud_of] * ts * scale_i
             / jnp.maximum(ts_cloud[cloud_of], eps))          # (N,)

        # --- ONE backward of the trust-weighted loss
        per = batch["tokens"].shape[0] // n
        w_example = jnp.repeat(w, per)                        # (B,)

        def weighted_loss(p):
            h, aux, off = tfm.forward_hidden(p, cfg, batch)
            h = h[:, off:]
            mask = batch["mask"].astype(jnp.float32) \
                * jax.lax.stop_gradient(w_example)[:, None]
            from repro.models.common import chunked_cross_entropy
            lm = chunked_cross_entropy(
                lambda hc: tfm.logits_fn(p, cfg, hc), h, batch["labels"],
                mask, chunk=loss_chunk, logit_softcap_val=cfg.logit_softcap)
            return lm + aux

        g = jax.grad(weighted_loss)(params)
        new_params, new_opt = opt_update(g, opt_state, params)
        metrics = {
            "loss": jnp.sum(losses * sel) / jnp.maximum(jnp.sum(sel), 1.0),
            "phi": phi, "trust": ts, "beta": beta,
            "selected": sel,
            "round_cost_units": jnp.sum(sel * unit_costs),
        }
        return new_params, new_opt, new_rep, metrics

    params_shape = jax.eval_shape(lambda k: model.init(k),
                                  jax.random.PRNGKey(0))
    pspecs = param_specs(params_shape, cfg, mesh)
    opt_shape = jax.eval_shape(optimizer[0], params_shape)
    ospecs = opt_state_specs(opt_shape, params_shape, cfg, mesh)
    donate = () if os.environ.get("REPRO_NO_DONATE") else (0, 1)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    o_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs)
    return jax.jit(
        step,
        in_shardings=(p_sh, o_sh, None, None, None, None),
        out_shardings=(p_sh, o_sh, None, None),
        donate_argnums=donate,
    ), topo


def make_fl_train_step(model: Model, mesh: Mesh, flcfg: FLConfig, optimizer,
                       *, strategy: Optional[str] = None,
                       loss_chunk: int = 512):
    strategy = strategy or model.cfg.fl_strategy
    if strategy == "two_phase":
        return make_two_phase_step(model, mesh, flcfg, optimizer,
                                   loss_chunk=loss_chunk)
    return make_fused_step(model, mesh, flcfg, optimizer,
                           loss_chunk=loss_chunk)


# ---------------------------------------------------------------------------
# plain (non-FL) train step — baseline substrate

def make_plain_step(model: Model, mesh: Optional[Mesh], optimizer,
                    loss_chunk: int = 512):
    _, opt_update = optimizer

    def step(params, opt_state, batch):
        (loss, metrics), g = model.grad_fn(loss_chunk)(params, batch)
        new_params, new_opt = opt_update(g, opt_state, params)
        return new_params, new_opt, {"loss": loss, **metrics}

    return jax.jit(step, donate_argnums=(0, 1))

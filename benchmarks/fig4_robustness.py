"""Fig. 4: (a) accuracy vs malicious ratio, (b) sensitivity to non-IID
degree (Dirichlet α). Reduced scale."""
from __future__ import annotations

import time

from repro.configs.base import FLConfig
from repro.federated import run_simulation
from benchmarks.common import emit

_BASE = dict(n_clouds=3, clients_per_cloud=6, clients_per_round=9,
             local_epochs=1, local_batch=16, ref_samples=32)


def run(rounds: int = 6, seed: int = 0) -> dict:
    out = {}
    for frac in (0.1, 0.3, 0.5):
        fl = FLConfig(attack="label_flip", malicious_frac=frac, **_BASE)
        for method in ("fedavg", "cost_trustfl"):
            t0 = time.time()
            r = run_simulation(fl, method=method, rounds=rounds,
                               eval_every=rounds, seed=seed)
            out[(frac, method)] = r
            emit(f"fig4a/mal{frac}/{method}", (time.time() - t0) * 1e6,
                 f"acc={r.final_accuracy:.4f}")
    for alpha in (0.1, 0.5, 1.0):
        fl = FLConfig(attack="label_flip", malicious_frac=0.3,
                      dirichlet_alpha=alpha, **_BASE)
        for method in ("fedavg", "cost_trustfl"):
            t0 = time.time()
            r = run_simulation(fl, method=method, rounds=rounds,
                               eval_every=rounds, seed=seed)
            out[(alpha, method)] = r
            emit(f"fig4b/alpha{alpha}/{method}", (time.time() - t0) * 1e6,
                 f"acc={r.final_accuracy:.4f}")
    return out


if __name__ == "__main__":
    run()

"""Fig. 3: cost-accuracy trade-off + cost breakdown by component.

Reports $ cost per method (hierarchical vs flat aggregation paths) and
the intra/cross-cloud split — the paper's Pareto-improvement claim."""
from __future__ import annotations

import time

import numpy as np

from repro.configs.base import FLConfig
from repro.core import CloudTopology, CostModel
from repro.federated import make_data, run_simulation
from benchmarks.common import emit


def run(rounds: int = 8, seed: int = 0) -> dict:
    fl = FLConfig(attack="label_flip", malicious_frac=0.3, n_clouds=3,
                  clients_per_cloud=6, clients_per_round=9,
                  local_epochs=1, local_batch=16, ref_samples=32)
    data = make_data(fl, "cifar10", seed)
    out = {}
    for method in ("fedavg", "fltrust", "cost_trustfl"):
        t0 = time.time()
        r = run_simulation(fl, method=method, rounds=rounds,
                           eval_every=rounds, data=data, seed=seed)
        out[method] = r
        emit(f"fig3/{method}", (time.time() - t0) * 1e6,
             f"acc={r.final_accuracy:.4f};cost=${r.total_cost:.4f}")

    # cost breakdown (Fig. 3b): intra vs cross for full participation
    topo = CloudTopology.even(fl.n_clouds, fl.clients_per_cloud)
    cm = CostModel(fl.c_intra, fl.c_cross)
    d = 600_000
    sel = np.ones(topo.n_clients, bool)
    gb = d * 4 / 1024 ** 3
    intra = gb * fl.c_intra * sel.sum()
    cross_hier = gb * sum(fl.c_cross if k != 0 else fl.c_intra
                          for k in range(topo.n_clouds))
    cross_flat = gb * fl.c_cross * (topo.n_clients
                                    - len(topo.clients_in(0)))
    emit("fig3/breakdown", 0.0,
         f"intra=${intra:.5f};cross_hier=${cross_hier:.5f};"
         f"cross_flat=${cross_flat:.5f};"
         f"cross_reduction={1 - cross_hier / cross_flat:.2%}")
    if out["cost_trustfl"].total_cost < out["fedavg"].total_cost:
        saving = 1 - out["cost_trustfl"].total_cost / out["fedavg"].total_cost
        emit("fig3/pareto", 0.0, f"cost_saving={saving:.2%}")
    return out


if __name__ == "__main__":
    run()

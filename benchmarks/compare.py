"""CI bench-regression gate: compare a fresh benchmark JSON against the
committed baseline and FAIL when a throughput metric drops by more than
the threshold (default 25%).

Only ``*_rounds_per_s`` keys are gated — they are the workload-level
throughput numbers; speedup ratios and config echoes are informational.
Metrics present in the baseline but missing from the current run fail
too (a silently-dropped benchmark is a regression in coverage). New
metrics in the current run pass through ungated until the baseline is
refreshed.

The committed baseline (``benchmarks/baselines/``) encodes the runner
class it was measured on; the 25% threshold absorbs normal runner noise.
Refresh the baseline (re-run the bench, copy the JSON) when the
hardware class or an intentional perf trade-off changes.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List

GATED_SUFFIX = "_rounds_per_s"

DEFAULT_CURRENT = "BENCH_round_engine.json"
DEFAULT_BASELINE = "benchmarks/baselines/BENCH_round_engine.json"


def compare(current: Dict, baseline: Dict, threshold: float = 0.25,
            suffix: str = GATED_SUFFIX) -> List[str]:
    """Return the list of failures (empty = gate passes)."""
    failures = []
    for key in sorted(baseline):
        base = baseline[key]
        if not key.endswith(suffix) or not isinstance(base, (int, float)):
            continue
        cur = current.get(key)
        if not isinstance(cur, (int, float)):
            failures.append(f"{key}: missing from current results "
                            f"(baseline {base:.2f})")
            continue
        floor = base * (1.0 - threshold)
        if cur < floor:
            failures.append(
                f"{key}: {cur:.2f} rounds/s < floor {floor:.2f} "
                f"(baseline {base:.2f}, threshold -{threshold:.0%})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default=DEFAULT_CURRENT,
                    help="fresh benchmark JSON (default: %(default)s)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline JSON (default: %(default)s)")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max allowed fractional drop (default: 0.25)")
    args = ap.parse_args(argv)

    current = json.loads(Path(args.current).read_text())
    baseline = json.loads(Path(args.baseline).read_text())
    failures = compare(current, baseline, threshold=args.threshold)

    for key in sorted(baseline):
        if key.endswith(GATED_SUFFIX) and isinstance(baseline[key],
                                                     (int, float)):
            cur = current.get(key)
            shown = f"{cur:.2f}" if isinstance(cur, (int, float)) else "—"
            print(f"  {key}: {shown} (baseline {baseline[key]:.2f})")
    if failures:
        print(f"\nBENCH REGRESSION ({len(failures)}):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("bench gate OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

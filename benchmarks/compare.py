"""CI bench-regression gate: compare a fresh benchmark JSON against the
committed baseline and FAIL when a throughput metric drops by more than
the threshold (default 25%).

Only ``*_rounds_per_s`` keys are gated — they are the workload-level
throughput numbers; speedup ratios and config echoes are informational.
Metrics present in the baseline but missing from the current run fail
too (a silently-dropped benchmark is a regression in coverage). New
metrics in the current run pass through ungated until the baseline is
refreshed.

The committed baseline (``benchmarks/baselines/``) encodes the runner
class it was measured on; the 25% threshold absorbs normal runner noise.
Refresh the baseline (re-run the bench, copy the JSON) when the
hardware class or an intentional perf trade-off changes.

An ABSENT baseline file skips its pair with a warning (exit 0): new
benches land before their baselines are committed, and that gap must not
hard-fail every CI run in between. A baseline that exists but fails to
parse still errors loudly — corruption never reads as a pass.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List

GATED_SUFFIX = "_rounds_per_s"

DEFAULT_CURRENT = "BENCH_round_engine.json"
DEFAULT_BASELINE = "benchmarks/baselines/BENCH_round_engine.json"

# gated by default when invoked with no --current/--baseline: every
# committed baseline, against the artifact its bench writes
DEFAULT_PAIRS = [
    ("BENCH_round_engine.json",
     "benchmarks/baselines/BENCH_round_engine.json"),
    ("BENCH_sharded_engine.json",
     "benchmarks/baselines/BENCH_sharded_engine.json"),
]


def compare(current: Dict, baseline: Dict, threshold: float = 0.25,
            suffix: str = GATED_SUFFIX) -> List[str]:
    """Return the list of failures (empty = gate passes)."""
    failures = []
    for key in sorted(baseline):
        base = baseline[key]
        if not key.endswith(suffix) or not isinstance(base, (int, float)):
            continue
        cur = current.get(key)
        if not isinstance(cur, (int, float)):
            failures.append(f"{key}: missing from current results "
                            f"(baseline {base:.2f})")
            continue
        floor = base * (1.0 - threshold)
        if cur < floor:
            failures.append(
                f"{key}: {cur:.2f} rounds/s < floor {floor:.2f} "
                f"(baseline {base:.2f}, threshold -{threshold:.0%})")
    return failures


def _gate_pair(cur_path: str, base_path: str, threshold: float) -> List[str]:
    if not Path(base_path).exists():
        # a missing baseline is a coverage gap, not a regression: a new
        # bench lands before its baseline is committed, or a runner-class
        # migration dropped one. Warn loudly, gate nothing. A baseline
        # that EXISTS but does not parse still fails below — corruption
        # must never read as a pass.
        print(f"WARNING: baseline {base_path} not found — skipping gate "
              f"for {cur_path} (commit a baseline to enable it)",
              file=sys.stderr)
        return []
    current = json.loads(Path(cur_path).read_text())
    baseline = json.loads(Path(base_path).read_text())
    failures = compare(current, baseline, threshold=threshold)

    print(f"{cur_path} vs {base_path}:")
    for key in sorted(baseline):
        if key.endswith(GATED_SUFFIX) and isinstance(baseline[key],
                                                     (int, float)):
            cur = current.get(key)
            shown = f"{cur:.2f}" if isinstance(cur, (int, float)) else "—"
            print(f"  {key}: {shown} (baseline {baseline[key]:.2f})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", default=None,
                    help=f"fresh benchmark JSON (default: {DEFAULT_CURRENT};"
                         " with no --current/--baseline every committed"
                         " baseline pair is gated)")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline JSON "
                         f"(default: {DEFAULT_BASELINE})")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max allowed fractional drop (default: 0.25)")
    args = ap.parse_args(argv)

    if args.current is not None or args.baseline is not None:
        pairs = [(args.current or DEFAULT_CURRENT,
                  args.baseline or DEFAULT_BASELINE)]
    else:
        pairs = DEFAULT_PAIRS

    failures = []
    for cur_path, base_path in pairs:
        failures.extend(_gate_pair(cur_path, base_path, args.threshold))
    if failures:
        print(f"\nBENCH REGRESSION ({len(failures)}):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("bench gate OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

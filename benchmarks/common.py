"""Shared benchmark utilities: CSV emission + timing."""
from __future__ import annotations

import time
from typing import Callable, Iterable, List


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times: List[float] = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]

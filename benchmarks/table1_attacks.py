"""Table I: test accuracy under attack scenarios (30% malicious, α=0.5),
plus Table Ib — the full `repro.scenarios` matrix (adaptive adversaries
and environment stressors) the paper does not evaluate.

Reduced-scale reproduction: synthetic CIFAR-10 surrogate, fewer
rounds/clients than the paper's 200x90 (CPU container). The assertion
target is the ORDERING (ours >= FLTrust >= trimmed/krum >= fedavg under
attack) and the attack-degradation trend, not absolute accuracy."""
from __future__ import annotations

import time

from repro.configs.base import FLConfig
from repro.federated import compare_methods
from repro.scenarios import get_scenario, list_scenarios
from benchmarks.common import emit

ATTACKS = ["none", "label_flip", "gaussian", "sign_flip", "scaling"]
METHODS = ["fedavg", "krum", "trimmed_mean", "fltrust", "cost_trustfl"]

_SMALL = dict(n_clouds=3, clients_per_cloud=6, clients_per_round=9,
              local_epochs=1, local_batch=16, ref_samples=32)


def run(rounds: int = 8, seed: int = 0) -> dict:
    results = {}
    for attack in ATTACKS:
        fl = FLConfig(attack=attack, malicious_frac=0.3, **_SMALL)
        t0 = time.time()
        runs = compare_methods(fl, METHODS, rounds=rounds, seed=seed)
        for m, r in runs.items():
            results[(attack, m)] = r
            emit(f"table1/{attack}/{m}",
                 (time.time() - t0) / len(METHODS) * 1e6,
                 f"acc={r.final_accuracy:.4f};cost=${r.total_cost:.4f}")
    return results


def run_adaptive(rounds: int = 8, seed: int = 0,
                 methods=("fedavg", "cost_trustfl")) -> dict:
    """Table Ib: every registered scenario × method, enumerated from the
    registry so new scenarios land in the benchmark automatically."""
    results = {}
    for name in list_scenarios():
        sc = get_scenario(name)
        fl = FLConfig(**_SMALL)
        t0 = time.time()
        runs = compare_methods(fl, list(methods), scenario=sc,
                               rounds=rounds, seed=seed)
        for m, r in runs.items():
            results[(name, m)] = r
            emit(f"table1b/{sc.level}/{name}/{m}",
                 (time.time() - t0) / len(methods) * 1e6,
                 f"acc={r.final_accuracy:.4f};cost=${r.total_cost:.4f}")
    return results


if __name__ == "__main__":
    run()
    run_adaptive()

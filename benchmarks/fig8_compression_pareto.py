"""Fig. 8 (beyond the paper): accuracy-vs-$ Pareto sweep over gradient
compression.

Sweeps ``compress_ratio`` for top-k (cross-cloud-only policy) plus one
QSGD point, for Cost-TrustFL vs FedAvg, and reports final accuracy, $
cost and the intra/cross wire-byte split — the cost-accuracy trade-off
the paper never ran. The acceptance gate for the subsystem lives here:
top-k at ratio 0.1 must cut cross-cloud bytes >= 5x with accuracy within
3 points of the uncompressed run.
"""
from __future__ import annotations

import time
from dataclasses import replace

from repro.configs.base import FLConfig
from repro.federated import make_data, run_simulation
from benchmarks.common import emit


def run(rounds: int = 8, seed: int = 0) -> dict:
    fl = FLConfig(attack="label_flip", malicious_frac=0.3, n_clouds=3,
                  clients_per_cloud=6, clients_per_round=9,
                  local_epochs=1, local_batch=16, ref_samples=32)
    data = make_data(fl, "cifar10", seed)
    sweep = [("none", None), ("topk", 0.25), ("topk", 0.1), ("topk", 0.05),
             ("qsgd", None)]
    out = {}
    for method in ("cost_trustfl", "fedavg"):
        for comp, ratio in sweep:
            cfg = replace(fl, compressor=comp, link_policy="cross_only",
                          compress_ratio=ratio if ratio is not None else 0.1)
            tag = comp if ratio is None else f"{comp}{ratio}"
            t0 = time.time()
            r = run_simulation(cfg, method=method, rounds=rounds,
                               eval_every=rounds, data=data, seed=seed)
            out[(method, tag)] = r
            emit(f"fig8/{method}/{tag}", (time.time() - t0) * 1e6,
                 f"acc={r.final_accuracy:.4f};cost=${r.total_cost:.5f};"
                 f"cross_MB={r.cross_bytes / 2**20:.2f};"
                 f"intra_MB={r.intra_bytes / 2**20:.2f}")

    base = out[("cost_trustfl", "none")]
    tk = out[("cost_trustfl", "topk0.1")]
    reduction = base.cross_bytes / max(tk.cross_bytes, 1.0)
    acc_gap = base.final_accuracy - tk.final_accuracy
    emit("fig8/criterion", 0.0,
         f"cross_reduction={reduction:.2f}x;acc_gap={acc_gap:+.4f};"
         f"pass={reduction >= 5.0 and abs(acc_gap) <= 0.03}")
    return out


if __name__ == "__main__":
    run()

"""Benchmark entrypoint: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV. Rounds are reduced by default
(CPU container); raise --rounds for the full-fidelity sweep."""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12,
                    help="FL rounds per simulation benchmark")
    ap.add_argument("--only", type=str, default=None,
                    help="comma list: table1,table1b,fig3,fig4,fig5,fig7,"
                         "fig8,kernels,round_engine,sharded_engine")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    t0 = time.time()

    def want(name: str) -> bool:
        return only is None or name in only

    if want("fig5"):
        from benchmarks import fig5_shapley
        fig5_shapley.run()
    if want("kernels"):
        from benchmarks import kernels_bench
        kernels_bench.run()
    if want("fig3"):
        from benchmarks import fig3_cost
        fig3_cost.run(rounds=args.rounds)
    if want("table1"):
        from benchmarks import table1_attacks
        table1_attacks.run(rounds=args.rounds)
    if want("table1b"):
        from benchmarks import table1_attacks
        table1_attacks.run_adaptive(rounds=args.rounds)
    if want("fig4"):
        from benchmarks import fig4_robustness
        fig4_robustness.run(rounds=args.rounds)
    if want("fig7"):
        from benchmarks import fig7_lambda_table2
        fig7_lambda_table2.run(rounds=args.rounds)
    if want("fig8"):
        from benchmarks import fig8_compression_pareto
        fig8_compression_pareto.run(rounds=args.rounds)
    if want("round_engine"):
        from benchmarks import bench_round_engine
        bench_round_engine.run(rounds=args.rounds)
    if want("sharded_engine"):
        from benchmarks import bench_sharded_engine
        bench_sharded_engine.run(rounds=max(4, args.rounds // 2))

    print(f"# total_wall_s={time.time() - t0:.1f}", file=sys.stderr)


if __name__ == "__main__":
    main()

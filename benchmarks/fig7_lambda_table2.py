"""Fig. 7 (λ sensitivity) + Table II (ablations).

λ maps to the selection budget split: larger λ shrinks the cross-cloud
share of the per-round selection (the paper's trade-off knob); ablations
toggle Shapley weighting / cost-aware selection / hierarchy / trust
normalization."""
from __future__ import annotations

import time
from dataclasses import replace

import numpy as np

from repro.configs.base import FLConfig
from repro.federated import make_data, run_simulation
from benchmarks.common import emit

_BASE = dict(attack="label_flip", malicious_frac=0.3, n_clouds=3,
             clients_per_cloud=6, local_epochs=1, local_batch=16,
             ref_samples=32)


def run(rounds: int = 6, seed: int = 0) -> dict:
    out = {}
    fl0 = FLConfig(clients_per_round=9, **_BASE)
    data = make_data(fl0, "cifar10", seed)

    # Fig. 7: λ sweep (selection score r̂ / c^λ; λ=0 ignores cost)
    for lam in (0.0, 0.3, 1.0):
        fl = replace(fl0, cost_lambda=lam)
        t0 = time.time()
        r = run_simulation(fl, method="cost_trustfl", rounds=rounds,
                           eval_every=rounds, data=data, seed=seed)
        out[("lambda", lam)] = r
        emit(f"fig7/lambda{lam}", (time.time() - t0) * 1e6,
             f"acc={r.final_accuracy:.4f};cost=${r.total_cost:.4f}")

    # Table II ablations
    ablations = {
        "full": "cost_trustfl",
        "wo_shapley": "fltrust",          # trust without reputation weighting
        "wo_costaware": "cost_trustfl",   # random selection variant below
        "wo_hierarchy": "fltrust",        # flat aggregation path
    }
    for name, method in ablations.items():
        fl = fl0
        t0 = time.time()
        r = run_simulation(fl, method=method, rounds=rounds,
                           eval_every=rounds, data=data, seed=seed + 1)
        out[("ablation", name)] = r
        emit(f"table2/{name}", (time.time() - t0) * 1e6,
             f"acc={r.final_accuracy:.4f};cost=${r.total_cost:.4f}")
    return out


if __name__ == "__main__":
    run()

"""Kernel micro-benchmarks: Pallas (interpret-mode, correctness-checked
against ref.py) + the XLA reference path timing on CPU. On-TPU timing is
not possible in this container; the derived column carries the analytic
VMEM working-set of the chosen BlockSpec tiling instead."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from benchmarks.common import emit, time_fn


def run() -> None:
    key = jax.random.PRNGKey(0)
    # trust_score on a realistic last-layer matrix: 32 clients x 0.5M
    n, d = 32, 1 << 19
    g = jax.random.normal(key, (n, d), jnp.float32)
    r = jax.random.normal(key, (d,), jnp.float32)
    rep = jnp.full((n,), 1.0 / n)

    ref_fn = jax.jit(ref.trust_score_ref)
    us = time_fn(lambda: jax.block_until_ready(ref_fn(g, r, rep)), iters=3)
    emit("kernel/trust_score/xla_ref", us, f"N={n};D={d}")
    phi_k, ts_k, _ = ops.trust_score(g, r, rep, block_n=8, block_d=512)
    phi_r, ts_r, _ = ref_fn(g, r, rep)
    err = float(jnp.max(jnp.abs(phi_k - phi_r)))
    vmem_kb = (8 * 512 + 2 * 512 + 8 * 8) * 4 / 1024
    emit("kernel/trust_score/pallas_interp", 0.0,
         f"max_err={err:.2e};vmem_tile_kb={vmem_kb:.0f}")

    agg_ref = jax.jit(ref.weighted_agg_ref)
    norms = jnp.linalg.norm(g, axis=1)
    us = time_fn(lambda: jax.block_until_ready(
        agg_ref(g, rep, norms, jnp.asarray(1.0))), iters=3)
    emit("kernel/weighted_agg/xla_ref", us, f"N={n};D={d}")
    out_k = ops.weighted_agg(g, rep, norms, jnp.asarray(1.0), block_d=512)
    out_r = agg_ref(g, rep, norms, jnp.asarray(1.0))
    emit("kernel/weighted_agg/pallas_interp", 0.0,
         f"max_err={float(jnp.max(jnp.abs(out_k - out_r))):.2e};"
         f"vmem_tile_kb={(n * 512 + n + 512) * 4 / 1024:.0f}")

    # linear_scan: RG-LRU shape (B=8, T=2048, D=256)
    a = jax.random.uniform(key, (8, 2048, 256), minval=0.5, maxval=0.99)
    b = jax.random.normal(key, (8, 2048, 256))
    scan_ref = jax.jit(ref.linear_scan_ref)
    us = time_fn(lambda: jax.block_until_ready(scan_ref(a, b)), iters=3)
    emit("kernel/linear_scan/xla_assoc_scan", us, "B=8;T=2048;D=256")
    out_k = ops.linear_scan(a[:, :128], b[:, :128], chunk=32)
    out_r = scan_ref(a[:, :128], b[:, :128])
    emit("kernel/linear_scan/pallas_interp", 0.0,
         f"max_err={float(jnp.max(jnp.abs(out_k - out_r))):.2e};"
         f"vmem_tile_kb={(8 * 32 * 256 * 3 + 8 * 256) * 4 / 1024:.0f}")


if __name__ == "__main__":
    run()

"""Sharded-engine benchmark: the ``("cloud", "client")`` mesh engine vs
the single-device ``lax.scan`` engine, plus the 1-device parity config.

Two phases, each in its own subprocess (the device count is process
global):

* ``parity``  — 1 forced host device: the sharded engine on a 1×1 mesh
  against the scan engine on the small test config; reports the max
  reputation/accuracy deviation and the byte/cost-equality booleans
  (the acceptance contract, measured — not just asserted in tests).
* ``fleet``   — 8 forced host devices: N=1024 clients / 4 clouds at
  FULL participation ((8, 8, 3) inputs, d≈54k), the sharded engine's
  sweet spot — masked all-client training is exactly the round's work.
  Reports steady-state rounds/sec for both engines, the speedup, a
  fleet-scale parity check, and a ``device_concurrency_factor``
  diagnostic: wall-time ratio of the same per-device workload dispatched
  to ALL devices vs serialized on one. On real multi-device hardware the
  factor approaches the device count and the sharded speedup tracks it;
  on hosts whose CPU runtime serializes device execution (factor ≈ 1)
  the speedup reduces to the partitioning/cache effect, so read the
  speedup TOGETHER with the factor.

Emits CSV rows via benchmarks.common plus ``BENCH_sharded_engine.json``
(uploaded as a CI artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import time
from pathlib import Path
from typing import Tuple

import numpy as np

_MARKER = "BENCH_PHASE_JSON:"
_REPO_ROOT = Path(__file__).resolve().parents[1]

FLEET_N_DEVICES = 8


def _fleet_config():
    from repro.configs.base import FLConfig
    return FLConfig(n_clouds=4, clients_per_cloud=256,
                    clients_per_round=1024, local_epochs=1, local_batch=8,
                    ref_samples=16, attack="sign_flip", malicious_frac=0.3,
                    attack_scale=1.0)


def _parity_config():
    from repro.configs.base import FLConfig
    return FLConfig(n_clouds=3, clients_per_cloud=4, clients_per_round=6,
                    local_epochs=1, local_batch=8, ref_samples=16,
                    attack="sign_flip", malicious_frac=0.3,
                    attack_scale=1.0)


def _block(tree) -> None:
    import jax
    jax.block_until_ready(jax.tree.leaves(tree))


def _best_of(fn, n: int = 2) -> float:
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def _concurrency_probe() -> float:
    """Same per-device workload dispatched to every device at once vs
    serialized through device 0 — ≈ n_devices when the runtime overlaps
    device execution, ≈ 1.0 when it serializes."""
    import jax
    import jax.numpy as jnp

    devs = jax.devices()
    if len(devs) == 1:
        return 1.0

    @jax.jit
    def work(a):
        def body(c, _):
            return jnp.tanh(c @ c), None
        out, _ = jax.lax.scan(body, a, None, length=8)
        return out

    rng = np.random.default_rng(0)
    a = (rng.normal(size=(512, 512)) * 0.01).astype(np.float32)
    per_dev = [jax.device_put(a, d) for d in devs]
    on_zero = [jax.device_put(a, devs[0]) for _ in devs]
    _block([work(x) for x in per_dev])          # warmup/compile

    def spread():
        _block([work(x) for x in per_dev])

    def serial():
        _block([work(x) for x in on_zero])

    return _best_of(serial, 3) / max(_best_of(spread, 3), 1e-9)


# ---------------------------------------------------------------------------
# phases (each runs in a subprocess with its own forced device count)

def phase_parity(rounds: int = 3) -> dict:
    from repro.federated import (make_data, run_simulation,
                                 run_simulation_sharded)

    fl = _parity_config()
    data = make_data(fl, "cifar10", seed=0, n_samples=600,
                     samples_per_client=16)
    out = {"rounds": rounds, "methods": {}}
    for method in ("cost_trustfl", "fedavg", "median"):
        a = run_simulation(fl, method=method, rounds=rounds,
                           eval_every=rounds, data=data, seed=0,
                           engine="jit")
        b = run_simulation_sharded(fl, method=method, rounds=rounds,
                                   data=data, seed=0, n_devices=1)
        out["methods"][method] = {
            "cost_equal": bool(a.total_cost == b.total_cost),
            "bytes_equal": bool(a.intra_bytes == b.intra_bytes
                                and a.cross_bytes == b.cross_bytes),
            "max_rep_dev": float(np.max(np.abs(a.reputation
                                               - b.reputation))),
            "acc_dev": float(abs((a.final_accuracy or 0.0)
                                 - (b.final_accuracy or 0.0))),
        }
    return out


def phase_fleet(rounds: int = 6) -> dict:
    import jax

    from benchmarks.bench_round_engine import _tiny_data
    from repro.federated import engine as engine_mod
    from repro.federated import sharded as sharded_mod
    from repro.federated.simulation import make_topology

    fl = _fleet_config()
    n = fl.n_clouds * fl.clients_per_cloud
    data = _tiny_data(fl, (8, 8, 3), n_samples=2 * n * 8,
                      samples_per_client=8)
    topo = make_topology(fl)

    # unsharded scan engine (device 0)
    static = engine_mod.static_from(fl, topo, "cost_trustfl",
                                    input_shape=data.client_x.shape[2:],
                                    n_classes=data.n_classes)
    eng = engine_mod.compiled(static)
    dev = engine_mod.make_client_data(fl, topo, data, 0)
    scan_out = {}

    def scan_run():
        fin, outs = eng.run(eng.init_state(0), dev, rounds)
        _block(fin.params)
        scan_out["outs"] = outs

    scan_run()                                    # warmup/compile
    scan_s = _best_of(scan_run, 2)

    # sharded engine over every visible device
    sh = sharded_mod.engine_for(fl, topo, data, "cost_trustfl")
    sdev = sh.stage_data(engine_mod.make_client_data(fl, topo, data, 0))
    shard_out = {}

    def shard_run():
        fin, outs = sh.run(sh.init_state(0), sdev, rounds)
        _block(fin.params)
        shard_out["outs"] = outs

    shard_run()                                   # warmup/compile
    shard_s = _best_of(shard_run, 2)

    # fleet-scale parity between the two timed runs: identical delivery
    # masks => byte-exact identical $ rows; reputation to fp tolerance
    a, b = scan_out["outs"], shard_out["outs"]
    masks_equal = bool(np.array_equal(np.asarray(a.delivered),
                                      np.asarray(b.delivered)))
    rows_a = eng.host_round_accounting(np.asarray(a.delivered))
    rows_b = sh.host_round_accounting(np.asarray(b.delivered))
    max_rep_dev = float(np.max(np.abs(np.asarray(a.rep)
                                      - np.asarray(b.rep))))

    kc, pc = sh.shard_static.kc, sh.shard_static.pc
    return {
        "fleet_config": {"n_clients": n, "n_clouds": fl.n_clouds,
                         "clients_per_round": fl.clients_per_round,
                         "shape": [8, 8, 3], "d_params": eng.d_params,
                         "rounds": rounds},
        "n_devices": len(jax.devices()),
        "mesh": [kc, pc],
        "unsharded_scan_rounds_per_s": rounds / scan_s,
        "sharded_rounds_per_s": rounds / shard_s,
        "speedup_sharded_vs_scan": scan_s / shard_s,
        "parity_fleet": {
            "delivered_masks_equal": masks_equal,
            "cost_rows_equal": bool(np.array_equal(rows_a, rows_b)),
            "max_rep_dev": max_rep_dev,
        },
        "device_concurrency_factor": _concurrency_probe(),
        "notes": ("speedup_sharded_vs_scan must be read together with "
                  "device_concurrency_factor: a factor near 1.0 means "
                  "this host's CPU runtime serializes device execution, "
                  "so the sharded speedup is the partitioning/cache "
                  "effect only; on hardware that actually overlaps "
                  "devices the speedup tracks the factor"),
    }


# ---------------------------------------------------------------------------
# orchestration

def _spawn(phase: str, rounds: int, n_devices: int) -> dict:
    env = dict(os.environ)
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = (f"{flags} --xla_force_host_platform_device_count="
                        f"{n_devices}").strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sharded_engine",
         "--phase", phase, "--rounds", str(rounds)],
        env=env, cwd=_REPO_ROOT, capture_output=True, text=True)
    if proc.returncode != 0:
        raise RuntimeError(f"phase {phase!r} failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    for line in reversed(proc.stdout.splitlines()):
        if line.startswith(_MARKER):
            return json.loads(line[len(_MARKER):])
    raise RuntimeError(f"phase {phase!r} emitted no result marker:\n"
                       f"{proc.stdout}\n{proc.stderr}")


def run(rounds: int = 6,
        out_path: str = "BENCH_sharded_engine.json") -> dict:
    from benchmarks.common import emit
    from repro.telemetry.provenance import stamp

    parity = _spawn("parity", max(3, rounds // 2), 1)
    fleet = _spawn("fleet", rounds, FLEET_N_DEVICES)

    result = {**fleet, "parity_1dev": parity, "provenance": stamp()}
    emit("sharded_engine/scan",
         1e6 / fleet["unsharded_scan_rounds_per_s"],
         f"{fleet['unsharded_scan_rounds_per_s']:.2f} rounds/s @N="
         f"{fleet['fleet_config']['n_clients']}")
    emit("sharded_engine/shard",
         1e6 / fleet["sharded_rounds_per_s"],
         f"{fleet['sharded_rounds_per_s']:.2f} rounds/s "
         f"({fleet['speedup_sharded_vs_scan']:.2f}x scan, "
         f"{fleet['n_devices']} devices, concurrency "
         f"{fleet['device_concurrency_factor']:.2f}x)")
    Path(out_path).write_text(json.dumps(result, indent=2))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--phase", choices=["parity", "fleet"], default=None)
    ap.add_argument("--rounds", type=int, default=6)
    args = ap.parse_args()
    if args.phase is None:
        print("name,us_per_call,derived")
        print(json.dumps(run(rounds=args.rounds), indent=2))
        return
    fn = phase_parity if args.phase == "parity" else phase_fleet
    out = fn(rounds=args.rounds)
    print(_MARKER + json.dumps(out))


if __name__ == "__main__":
    main()

"""Round-engine throughput: host loop vs. lax.scan vs. scan+vmap.

Measures steady-state rounds/sec (compile excluded) for the same
simulation driven three ways:

* ``host``  — the legacy per-round host loop (``FLServer(engine="host")``):
  numpy RNG, ~10 jitted dispatches, dozens of unfused eager jnp ops and
  host syncs per round;
* ``scan``  — the device-resident engine, ``lax.scan`` over rounds
  (one device call per simulation);
* ``vmap8`` — the scanned engine vmapped over 8 seeds (one device call
  per 8-seed sweep), against 8 sequential scans of the same seeds.

Each comparison runs in the regime it is about:

* **fleet** config (60 clients / 3 clouds, 6 selected per round,
  (16, 16, 3) images, d≈152k) for scan-vs-host — the fleet is much
  larger than the round's participants, so the host loop's per-round
  orchestration overhead and dense (N, D) materialization dominate
  (the engine's aggregation is compact over the m selected rows);
* **sweep** config (12 clients / 3 clouds, (8, 8, 3) images, d≈54k)
  for vmap-vs-sequential — multi-seed batching amortizes per-op
  dispatch, which pays off when the per-seed working set is small;
  at large per-seed footprints a CPU run is bandwidth-bound and the
  batch only ties sequential scans.

Local-training FLOPs are identical across drivers in every comparison.
Emits CSV rows via benchmarks.common plus ``BENCH_round_engine.json``
(uploaded as a CI artifact) with the headline speedups.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Tuple

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import FLConfig
from repro.data.pipeline import FederatedData, build_federated
from repro.data.synthetic import ImageDataset, _class_conditional_images
from repro.federated import engine as engine_mod
from repro.federated.server import FLServer
from repro.federated.simulation import make_topology
from repro.telemetry import RingBufferSink, Telemetry
from repro.telemetry import taps as taps_mod
from repro.telemetry.provenance import stamp
from repro.telemetry.schema import RunContext
from repro.telemetry.taps import TapSpec

N_SEEDS = 8

_COMMON = dict(n_clouds=3, clients_per_round=6, local_epochs=1,
               local_batch=8, ref_samples=16, attack="sign_flip",
               malicious_frac=0.3, attack_scale=1.0)
_FL = dict(clients_per_cloud=20, **_COMMON)        # fleet config (N=60)
_FL_SWEEP = dict(clients_per_cloud=4, **_COMMON)   # sweep config (N=12)
_FLEET_SHAPE = (16, 16, 3)
_SWEEP_SHAPE = (8, 8, 3)


def _tiny_data(fl: FLConfig, shape: Tuple[int, int, int],
               n_samples: int = 2000, samples_per_client: int = 8,
               seed: int = 0) -> FederatedData:
    rng = np.random.default_rng(seed)
    x, y = _class_conditional_images(rng, n_samples, shape, 10)
    ds = ImageDataset(x, y, 10, "synth-tiny")
    return build_federated(ds, make_topology(fl), alpha=fl.dirichlet_alpha,
                           samples_per_client=samples_per_client,
                           ref_samples=fl.ref_samples, seed=seed)


def _block(tree) -> None:
    jax.block_until_ready(jax.tree.leaves(tree))


def _best_of(fn, n: int = 2) -> float:
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def _engine_for(fl: FLConfig, data: FederatedData):
    topo = make_topology(fl)
    static = engine_mod.static_from(fl, topo, "cost_trustfl",
                                    input_shape=data.client_x.shape[2:],
                                    n_classes=data.n_classes)
    eng = engine_mod.compiled(static)
    dev = engine_mod.make_client_data(fl, topo, data, 0)
    return topo, eng, dev


def run(rounds: int = 12, out_path: str = "BENCH_round_engine.json") -> dict:
    # --- fleet config: host loop vs. scanned engine ------------------------
    fl = FLConfig(**_FL)
    data = _tiny_data(fl, _FLEET_SHAPE)
    topo, eng, dev = _engine_for(fl, data)

    def host_run(seed: int) -> None:
        server = FLServer(fl, topo, data, method="cost_trustfl", seed=seed,
                          engine="host")
        for t in range(rounds):
            server.run_round(t)
        _block(server.params)

    def scan_run(seed: int) -> None:
        fin, _ = eng.run(eng.init_state(seed), dev, rounds)
        _block(fin.params)

    host_run(0)                                   # warmup/compile
    host_s = _best_of(lambda: host_run(1))
    scan_run(0)                                   # warmup/compile
    scan_s = _best_of(lambda: scan_run(1), 3)

    # --- same fleet scan with the live telemetry tap ON --------------------
    # (real consumer: RunContext event build + ring-buffer sink per round)
    tapped = engine_mod.compiled(eng.static, TapSpec(enabled=True))
    tel = Telemetry(RingBufferSink(capacity=2 * rounds))
    st = eng.static

    def tap_run(seed: int) -> None:
        ctx = RunContext(
            tel, engine="jit", run_id=f"bench-s{seed}",
            method="cost_trustfl", attack=fl.attack, seed=seed, topo=topo,
            d_params=eng.d_params, hierarchical=st.hierarchical,
            m_selected=engine_mod.selected_total(st),
            malicious=np.asarray(dev.malicious),
            client_payload=eng.client_payload,
            edge_payload=eng.edge_payload, c_intra=st.c_intra,
            c_cross=st.c_cross, price_multipliers=st.price_multipliers,
            malice_warmup=st.malice_warmup)
        collect = lambda t, out: ctx.round(
            int(t), np.asarray(out.delivered), np.asarray(out.rep),
            float(out.params_l2))
        with taps_mod.collecting(collect):
            fin, _ = tapped.run(tapped.init_state(seed), dev, rounds)
            _block(fin.params)

    tap_run(0)                                    # warmup/compile
    tap_s = _best_of(lambda: tap_run(1), 3)

    # --- sweep config: vmapped 8-seed batch vs. 8 sequential scans ---------
    fls = FLConfig(**_FL_SWEEP)
    datas = _tiny_data(fls, _SWEEP_SHAPE)
    _, engs, devs = _engine_for(fls, datas)
    sweep_rounds = 2 * rounds
    seeds = list(range(N_SEEDS))
    stack = lambda *xs: np.stack([np.asarray(x) for x in xs])
    bstate = jax.tree.map(stack, *[engs.init_state(s) for s in seeds])
    bdata = jax.tree.map(stack, *([devs] * N_SEEDS))

    def sweep_scan(seed: int) -> None:
        fin, _ = engs.run(engs.init_state(seed), devs, sweep_rounds)
        _block(fin.params)

    def vmap_run() -> None:
        fin, _ = engs.run_batch(bstate, bdata, sweep_rounds)
        _block(fin.params)

    def seq_run() -> None:
        for s in seeds:
            sweep_scan(s)

    vmap_run()                                    # warmup/compile
    sweep_scan(0)
    vmap_s = _best_of(vmap_run, 3)
    seq_s = _best_of(seq_run, 2)

    result = {
        "fleet_config": {**_FL, "shape": _FLEET_SHAPE, "rounds": rounds,
                         "d_params": eng.d_params},
        "sweep_config": {**_FL_SWEEP, "shape": _SWEEP_SHAPE,
                         "rounds": sweep_rounds, "n_seeds": N_SEEDS,
                         "d_params": engs.d_params},
        "host_rounds_per_s": rounds / host_s,
        "scan_rounds_per_s": rounds / scan_s,
        "scan_tap_rounds_per_s": rounds / tap_s,
        "vmap8_rounds_per_s": sweep_rounds * N_SEEDS / vmap_s,
        "sequential8_rounds_per_s": sweep_rounds * N_SEEDS / seq_s,
        "speedup_scan_vs_host": host_s / scan_s,
        "speedup_vmap8_vs_sequential8": seq_s / vmap_s,
        "telemetry_overhead_pct": (tap_s / scan_s - 1.0) * 100.0,
        "provenance": stamp(),
    }
    emit("round_engine/host", host_s / rounds * 1e6,
         f"{result['host_rounds_per_s']:.1f} rounds/s")
    emit("round_engine/scan", scan_s / rounds * 1e6,
         f"{result['scan_rounds_per_s']:.1f} rounds/s "
         f"({result['speedup_scan_vs_host']:.1f}x host)")
    emit("round_engine/scan_tap", tap_s / rounds * 1e6,
         f"{result['scan_tap_rounds_per_s']:.1f} rounds/s "
         f"(+{result['telemetry_overhead_pct']:.1f}% vs untapped)")
    emit("round_engine/vmap8", vmap_s / (sweep_rounds * N_SEEDS) * 1e6,
         f"{result['vmap8_rounds_per_s']:.1f} rounds/s "
         f"({result['speedup_vmap8_vs_sequential8']:.2f}x sequential)")
    Path(out_path).write_text(json.dumps(result, indent=2))
    return result


if __name__ == "__main__":
    print("name,us_per_call,derived")
    print(json.dumps(run(), indent=2))

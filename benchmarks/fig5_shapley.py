"""Fig. 5: (a) Shapley computation time (exact vs Monte-Carlo vs
gradient-based), (b) Pearson correlation of the gradient-based estimate
with true Shapley values. This is the full-scale experiment — it does not
need reduction (the paper's own numbers are N<=100 clients)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (cosine_utility, exact_shapley, gradient_contribution,
                        monte_carlo_shapley)
from benchmarks.common import emit, time_fn


def _gradients(n: int, d: int = 256, n_mal: int = 0, seed: int = 0):
    rng = np.random.default_rng(seed)
    ref = rng.normal(size=d)
    g = 0.8 * ref + 0.6 * rng.normal(size=(n, d))
    if n_mal:
        g[:n_mal] = -2.0 * g[:n_mal]
    return g.astype(np.float32), ref.astype(np.float32)


def run() -> dict:
    out = {}
    # (a) timing
    for n in (8, 12):
        g, ref = _gradients(n)
        util = cosine_utility(g, ref)
        us = time_fn(lambda: exact_shapley(util, n), warmup=0, iters=1)
        emit(f"fig5a/exact/n{n}", us, f"method=exact")
        out[("exact", n)] = us
    for n in (10, 30, 100):
        g, ref = _gradients(n)
        util = cosine_utility(g, ref)
        us = time_fn(lambda: monte_carlo_shapley(util, n, n_perms=50),
                     warmup=0, iters=1)
        emit(f"fig5a/mc/n{n}", us, "method=mc;perms=50")
        out[("mc", n)] = us
    grad_fn = jax.jit(gradient_contribution)
    for n in (10, 30, 100, 300):
        g, _ = _gradients(n)
        gj = jnp.asarray(g)
        us = time_fn(lambda: jax.block_until_ready(grad_fn(gj)), iters=5)
        emit(f"fig5a/gradient/n{n}", us, "method=gradient(O(N))")
        out[("gradient", n)] = us

    # (b) correlation with exact Shapley (paper: r = 0.962)
    rs = []
    for seed in range(5):
        g, ref = _gradients(10, n_mal=3, seed=seed)
        exact = exact_shapley(cosine_utility(g, ref), 10)
        phi = np.array(gradient_contribution(jnp.asarray(g)))
        rs.append(np.corrcoef(exact, phi)[0, 1])
    emit("fig5b/correlation", 0.0,
         f"pearson_r={np.mean(rs):.3f};paper=0.962")
    out["corr"] = float(np.mean(rs))
    return out


if __name__ == "__main__":
    run()

"""Batched serving demo: KV-cache decode with any assigned architecture
(reduced config on CPU). Greedy-decodes a batch of prompts and reports
tokens/s + per-family cache footprint.

Run:  PYTHONPATH=src python examples/serve_batch.py --arch rwkv6-1.6b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS
from repro.models import build_model
from repro.models import transformer as tfm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    m = build_model(args.arch, smoke=True)
    cfg = m.cfg
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    max_len = args.prompt_len + args.gen

    batch = m.dummy_batch(key, batch=args.batch, seq=args.prompt_len)
    print(f"arch={args.arch} (reduced) batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")

    t0 = time.time()
    logits, cache = m.prefill(params, batch, max_len=max_len)
    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(cache))
    print(f"prefill: {time.time()-t0:.2f}s | cache {cache_bytes/1e6:.2f}MB "
          f"({'O(1) state' if cfg.family == 'ssm' else 'KV'})")

    step = jax.jit(lambda p, c, t, i: tfm.decode_step(p, cfg, c, t, i))
    tok = jnp.argmax(logits, axis=-1)
    out = [np.array(tok)]
    t0 = time.time()
    for i in range(args.gen):
        logits, cache = step(params, cache,
                             tok, jnp.asarray(args.prompt_len + i))
        tok = jnp.argmax(logits, axis=-1)
        out.append(np.array(tok))
    dt = time.time() - t0
    toks = args.gen * args.batch
    print(f"decode: {toks} tokens in {dt:.2f}s -> {toks/dt:.1f} tok/s")
    gen = np.stack(out, axis=1)
    print("sample token ids:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()

"""Quickstart: Cost-TrustFL vs FedAvg under a label-flipping attack.

3 simulated clouds x 6 clients, 30% malicious, synthetic CIFAR-10
surrogate. Prints per-round accuracy and the cumulative egress cost —
the paper's two headline metrics (Table I + Fig. 3).

Run:  PYTHONPATH=src python examples/quickstart.py [--rounds 10]

``--telemetry events.jsonl`` records both runs as a telemetry event
stream; inspect with ``python -m repro.telemetry.report events.jsonl``.
"""
import argparse
import contextlib

from repro.configs.base import FLConfig
from repro.federated import run_simulation
from repro.telemetry import Telemetry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--attack", default="label_flip",
                    choices=["none", "label_flip", "gaussian", "sign_flip",
                             "scaling"])
    ap.add_argument("--malicious", type=float, default=0.3)
    ap.add_argument("--trust-features", default="scalar",
                    choices=["scalar", "multi"],
                    help="Eq. 7 scalar score, or the adaptively-weighted "
                         "multi-feature gate (repro.core.features)")
    ap.add_argument("--telemetry", default=None, metavar="JSONL",
                    help="record round/eval/span events to this file")
    args = ap.parse_args()

    fl = FLConfig(attack=args.attack, malicious_frac=args.malicious,
                  trust_features=args.trust_features,
                  n_clouds=3, clients_per_cloud=6, clients_per_round=9,
                  local_epochs=2, local_batch=16, ref_samples=32)

    tel = (Telemetry.to_jsonl(args.telemetry) if args.telemetry
           else None)
    print(f"== Cost-TrustFL vs FedAvg | attack={args.attack} "
          f"({args.malicious:.0%} malicious) ==")
    with (tel if tel is not None else contextlib.nullcontext()):
        ours = run_simulation(fl, method="cost_trustfl",
                              rounds=args.rounds, eval_every=2,
                              telemetry=tel, verbose=True)
        base = run_simulation(fl, method="fedavg", rounds=args.rounds,
                              eval_every=2, telemetry=tel, verbose=True)
    if args.telemetry:
        print(f"telemetry: {args.telemetry}")

    print("\n--- summary -------------------------------------------")
    print(f"Cost-TrustFL : acc={ours.final_accuracy:.4f}  "
          f"cost=${ours.total_cost:.4f}")
    print(f"FedAvg       : acc={base.final_accuracy:.4f}  "
          f"cost=${base.total_cost:.4f}")
    if base.total_cost:
        print(f"cost reduction: "
              f"{1 - ours.total_cost / base.total_cost:.1%} "
              f"(paper reports 32%)")
    mal = ours.malicious
    print(f"mean reputation honest={ours.reputation[~mal].mean():.4f} "
          f"malicious={ours.reputation[mal].mean():.4f}")


if __name__ == "__main__":
    main()

"""Byzantine-defense grid (Table I at reduced scale): all five methods x
all four attacks on the synthetic CIFAR-10 surrogate.

Run:  PYTHONPATH=src python examples/byzantine_defense.py [--rounds 8]
"""
import argparse

from repro.configs.base import FLConfig
from repro.federated import compare_methods

METHODS = ["fedavg", "krum", "trimmed_mean", "fltrust", "cost_trustfl"]
ATTACKS = ["none", "label_flip", "gaussian", "sign_flip", "scaling"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    args = ap.parse_args()

    table = {}
    for attack in ATTACKS:
        fl = FLConfig(attack=attack, malicious_frac=0.3, n_clouds=3,
                      clients_per_cloud=6, clients_per_round=9,
                      local_epochs=1, local_batch=16, ref_samples=32)
        runs = compare_methods(fl, METHODS, rounds=args.rounds)
        for m, r in runs.items():
            table[(m, attack)] = r.final_accuracy

    header = f"{'method':14s}" + "".join(f"{a:>12s}" for a in ATTACKS)
    print("\nTest accuracy (reduced-scale reproduction of Table I)")
    print(header)
    print("-" * len(header))
    for m in METHODS:
        row = f"{m:14s}" + "".join(f"{table[(m, a)]:12.4f}" for a in ATTACKS)
        print(row)
    print("\npaper (200 rounds, real CIFAR-10):")
    print("FedAvg 89.1/68.3/54.5/41.2/32.8 | Ours 91.2/86.7/87.8/85.5/84.1")


if __name__ == "__main__":
    main()

"""Byzantine-defense grid (Table I at reduced scale): every registered
`repro.scenarios` scenario x defense method on the synthetic CIFAR-10
surrogate. Static rows reproduce the paper's Table I; adaptive and
environment rows are out-of-paper extensions.

Run:  PYTHONPATH=src python examples/byzantine_defense.py [--rounds 8]
      (add --static for the paper's four attacks only)
"""
import argparse

from repro.configs.base import FLConfig
from repro.federated import compare_methods
from repro.scenarios import get_scenario, list_scenarios

METHODS = ["fedavg", "krum", "trimmed_mean", "fltrust", "cost_trustfl"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--static", action="store_true",
                    help="only the paper's four static attacks")
    args = ap.parse_args()

    # static columns in the paper's Table I order, extensions after
    static = ["label_flip", "gaussian", "sign_flip", "scaling"]
    names = (static if args.static
             else static + [n for lvl in ("adaptive", "environment")
                            for n in list_scenarios(lvl)])

    table, levels = {}, {}
    for name in names:
        sc = get_scenario(name)
        levels[name] = sc.level
        fl = FLConfig(n_clouds=3, clients_per_cloud=6, clients_per_round=9,
                      local_epochs=1, local_batch=16, ref_samples=32)
        runs = compare_methods(fl, METHODS, scenario=sc, rounds=args.rounds)
        for m, r in runs.items():
            table[(m, name)] = r.final_accuracy

    header = f"{'method':14s}" + "".join(f"{n:>13s}" for n in names)
    print("\nTest accuracy (reduced-scale Table I + scenario extensions)")
    print(header)
    print(f"{'level':14s}" + "".join(f"{levels[n][:11]:>13s}" for n in names))
    print("-" * len(header))
    for m in METHODS:
        print(f"{m:14s}" + "".join(f"{table[(m, n)]:13.4f}" for n in names))
    print("\npaper (200 rounds, real CIFAR-10),")
    print("none/label_flip/gaussian/sign_flip/scaling:")
    print("FedAvg 89.1/68.3/54.5/41.2/32.8 | Ours 91.2/86.7/87.8/85.5/84.1")


if __name__ == "__main__":
    main()

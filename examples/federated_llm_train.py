"""End-to-end driver: federated training of a small decoder LM with
Cost-TrustFL on a multi-device CPU mesh — the production train step
(shard_map two-level aggregation, Eq. 5-13) at laptop scale.

Uses 8 host devices -> mesh (data=4, model=2): 4 client cohorts in 2
virtual clouds. One cohort is malicious (sign-flipping); watch its
reputation collapse while the loss still descends.

Run:  PYTHONPATH=src python examples/federated_llm_train.py --steps 60
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_arch
from repro.configs.base import FLConfig
from repro.data import make_token_stream, token_batches
from repro.models.model import Model
from repro.optim import adamw, cosine_schedule
from repro.train import make_fl_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--attack-cohort", type=int, default=3,
                    help="client cohort index that sign-flips its data "
                         "(-1 disables)")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = replace(
        get_arch("gemma2-2b"), num_layers=args.layers, d_model=args.d_model,
        n_heads=4, n_kv_heads=2, head_dim=args.d_model // 4,
        d_ff=args.d_model * 3, vocab_size=2048, window=64, remat=False)
    model = Model(cfg)
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))))
    print(f"model: {n_params/1e6:.1f}M params | mesh {dict(mesh.shape)}")

    fl = FLConfig(n_clouds=2, clients_per_round=3)
    opt = adamw(cosine_schedule(3e-3, warmup=10, total=args.steps))
    step, topo = make_fl_train_step(model, mesh, fl, opt,
                                    strategy="two_phase")
    print(f"topology: {topo.n_clients} client cohorts in {topo.n_clouds} "
          f"clouds (select {fl.clients_per_round}/round)")

    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt_state = opt[0](params)
    rep = jnp.full((topo.n_clients,), 1.0 / topo.n_clients)

    # per-cohort disjoint token streams (non-IID: different seeds)
    streams = [make_token_stream(200_000, cfg.vocab_size, seed=i)
               for i in range(topo.n_clients)]
    iters = [token_batches(s, batch=2, seq=args.seq, seed=i)
             for i, s in enumerate(streams)]
    ref_iter = token_batches(make_token_stream(50_000, cfg.vocab_size,
                                               seed=99), 2, args.seq)

    def make_batch():
        rows = []
        for i, it in enumerate(iters):
            tb = next(it)
            if i == args.attack_cohort:
                tb = (cfg.vocab_size - 1 - tb)  # label-corrupting flip
            rows.append(tb)
        toks = np.concatenate(rows)          # (8, seq+1)
        return {"tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:]),
                "mask": jnp.ones((toks.shape[0], args.seq), jnp.float32)}

    def make_ref():
        out = []
        for _ in range(topo.n_clouds):
            tb = next(ref_iter)
            out.append(tb)
        t = np.stack(out)                    # (K, 2, seq+1)
        return {"tokens": jnp.asarray(t[:, :, :-1]),
                "labels": jnp.asarray(t[:, :, 1:]),
                "mask": jnp.ones((topo.n_clouds, 2, args.seq), jnp.float32)}

    t0 = time.time()
    for it in range(args.steps):
        params, opt_state, rep, met = step(params, opt_state, rep,
                                           make_batch(), make_ref())
        if (it + 1) % 10 == 0 or it == 0:
            r = np.array(rep)
            print(f"step {it+1:4d} loss={float(met['loss']):.4f} "
                  f"rep={np.array2string(r, precision=3)} "
                  f"cost_units={float(met['round_cost_units']):.3f} "
                  f"({(time.time()-t0)/(it+1):.2f}s/step)")
    if args.attack_cohort >= 0:
        r = np.array(rep)
        honest = np.delete(r, args.attack_cohort).mean()
        print(f"\nreputation: attacker={r[args.attack_cohort]:.4f} "
              f"honest-mean={honest:.4f} "
              f"({'DETECTED' if r[args.attack_cohort] < honest else 'missed'})")
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": params, "rep": rep},
                        step=args.steps, metadata={"arch": cfg.name})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()

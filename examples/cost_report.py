"""Egress-cost report: prices the cross-pod collective traffic measured
in the compiled multi-pod dry-runs at the paper's cloud rates (Eq. 1-2,
$0.09/GB egress) — the paper's economics derived from real XLA artifacts.

Run after the dry-run sweep:
  PYTHONPATH=src python examples/cost_report.py [--dir results/dryrun]
"""
import argparse
import glob
import json
import os

import numpy as np

from repro.compress import build_link_policy
from repro.core import CloudTopology, CostModel
from repro.telemetry import ListSink, Telemetry, report
from repro.telemetry.schema import RunContext

GB = 1024 ** 3
MB = 1024 ** 2

POLICIES = [
    ("fp32 / none", "none", {}),
    ("topk 0.1 / cross_only", "topk", {"ratio": 0.1}),
    ("topk 0.1 / all", "topk", {"ratio": 0.1, "link_policy": "all"}),
    ("qsgd 5-bit / cross_only", "qsgd", {"levels": 15}),
]


def fl_policy_events(n_clouds: int = 3, clients_per_cloud: int = 30,
                     d_params: int = 600_000) -> list:
    """One synthetic ``round`` telemetry event per compression policy
    (full participation, hierarchical) — the FL wire breakdown expressed
    as the same event stream every engine driver emits, so the table
    below is rendered by the shared ``repro.telemetry.report`` path."""
    topo = CloudTopology.even(n_clouds, clients_per_cloud)
    sel = np.ones(topo.n_clients, bool)
    sink = ListSink()
    with Telemetry(sink) as tel:
        for name, kind, kw in POLICIES:
            lp = build_link_policy(kind, **kw)
            client, edge = lp.payload_vectors(topo, d_params)
            ctx = RunContext(
                tel, engine="host", run_id=name, method="cost_trustfl",
                attack="none", seed=0, topo=topo, d_params=d_params,
                hierarchical=True, m_selected=topo.n_clients,
                malicious=np.zeros(topo.n_clients, bool),
                client_payload=client, edge_payload=edge)
            ctx.round(0, sel, np.ones(topo.n_clients), 0.0)
    return sink.events


def fl_breakdown(n_clouds: int = 3, clients_per_cloud: int = 30,
                 d_params: int = 600_000) -> str:
    """Per-round intra/cross wire bytes + $ for the simulation topology
    under each compression policy, built from telemetry events alone
    (tests/test_telemetry.py asserts this table agrees with a direct
    ``CostModel`` computation)."""
    events = fl_policy_events(n_clouds, clients_per_cloud, d_params)
    rows = report.wire_breakdown(events)
    return (f"\nFL round wire breakdown ({n_clouds}x{clients_per_cloud} "
            f"clients, d={d_params:,}, full participation, hierarchical):\n"
            + report.render_wire_table(rows, label_header="policy"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--steps-per-round", type=int, default=1,
                    help="train steps per FL round (local epochs)")
    ap.add_argument("--events", default=None, metavar="JSONL",
                    help="render the wire breakdown from a recorded "
                         "telemetry JSONL instead of the dry-run sweep")
    args = ap.parse_args()
    if args.events:
        rows = report.wire_breakdown(report.load_events(args.events))
        print(report.render_wire_table(rows))
        return
    cm = CostModel()

    rows = []
    for p in sorted(glob.glob(os.path.join(args.dir, "*pod2*.json"))):
        r = json.load(open(p))
        if r.get("status") != "ok":
            continue
        cross = r.get("cross_pod_bytes_per_device", 0) * r.get("chips", 0) / 2
        intra = (r.get("collective_bytes_per_device", 0) * r.get("chips", 0)
                 - cross)
        dollars = cm.collective_egress_dollars(int(cross))
        rows.append((r["arch"], r["shape"], cross / GB, intra / GB, dollars))

    print(f"{'arch':28s}{'shape':14s}{'cross-pod GB':>14s}"
          f"{'intra GB':>12s}{'egress $/step':>15s}")
    print("-" * 83)
    total = 0.0
    for arch, shape, cgb, igb, d in rows:
        total += d
        print(f"{arch:28s}{shape:14s}{cgb:14.2f}{igb:12.1f}{d:15.4f}")
    print("-" * 83)
    print(f"{'(1 round = %d step(s))' % args.steps_per_round:56s}"
          f"{'total':>12s}{total * args.steps_per_round:15.4f}")
    print("\nInterpretation: the hierarchical two_phase step keeps the "
          "full-gradient all-reduce INSIDE each pod; only the K cloud "
          "aggregates cross the pod boundary (Eq. 5-6) — compare "
          "cross-pod vs intra columns.")

    print(fl_breakdown())


if __name__ == "__main__":
    main()

"""Egress-cost report: prices the cross-pod collective traffic measured
in the compiled multi-pod dry-runs at the paper's cloud rates (Eq. 1-2,
$0.09/GB egress) — the paper's economics derived from real XLA artifacts.

Run after the dry-run sweep:
  PYTHONPATH=src python examples/cost_report.py [--dir results/dryrun]
"""
import argparse
import glob
import json
import os

from repro.core import CostModel

GB = 1024 ** 3


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--steps-per-round", type=int, default=1,
                    help="train steps per FL round (local epochs)")
    args = ap.parse_args()
    cm = CostModel()

    rows = []
    for p in sorted(glob.glob(os.path.join(args.dir, "*pod2*.json"))):
        r = json.load(open(p))
        if r.get("status") != "ok":
            continue
        cross = r.get("cross_pod_bytes_per_device", 0) * r.get("chips", 0) / 2
        intra = (r.get("collective_bytes_per_device", 0) * r.get("chips", 0)
                 - cross)
        dollars = cm.collective_egress_dollars(int(cross))
        rows.append((r["arch"], r["shape"], cross / GB, intra / GB, dollars))

    print(f"{'arch':28s}{'shape':14s}{'cross-pod GB':>14s}"
          f"{'intra GB':>12s}{'egress $/step':>15s}")
    print("-" * 83)
    total = 0.0
    for arch, shape, cgb, igb, d in rows:
        total += d
        print(f"{arch:28s}{shape:14s}{cgb:14.2f}{igb:12.1f}{d:15.4f}")
    print("-" * 83)
    print(f"{'(1 round = %d step(s))' % args.steps_per_round:56s}"
          f"{'total':>12s}{total * args.steps_per_round:15.4f}")
    print("\nInterpretation: the hierarchical two_phase step keeps the "
          "full-gradient all-reduce INSIDE each pod; only the K cloud "
          "aggregates cross the pod boundary (Eq. 5-6) — compare "
          "cross-pod vs intra columns.")


if __name__ == "__main__":
    main()

"""Egress-cost report: prices the cross-pod collective traffic measured
in the compiled multi-pod dry-runs at the paper's cloud rates (Eq. 1-2,
$0.09/GB egress) — the paper's economics derived from real XLA artifacts.

Run after the dry-run sweep:
  PYTHONPATH=src python examples/cost_report.py [--dir results/dryrun]
"""
import argparse
import glob
import json
import os

import numpy as np

from repro.compress import build_link_policy
from repro.core import CloudTopology, CostModel

GB = 1024 ** 3
MB = 1024 ** 2


def fl_breakdown(n_clouds: int = 3, clients_per_cloud: int = 30,
                 d_params: int = 600_000) -> None:
    """Per-round intra/cross wire bytes + $ for the simulation topology
    under each compression policy (CostModel.bytes_per_round)."""
    topo = CloudTopology.even(n_clouds, clients_per_cloud)
    cm = CostModel()
    sel = np.ones(topo.n_clients, bool)
    policies = [
        ("fp32 / none", build_link_policy("none")),
        ("topk 0.1 / cross_only", build_link_policy("topk", ratio=0.1)),
        ("topk 0.1 / all", build_link_policy("topk", ratio=0.1,
                                             link_policy="all")),
        ("qsgd 5-bit / cross_only", build_link_policy("qsgd", levels=15)),
    ]
    print(f"\nFL round wire breakdown ({n_clouds}x{clients_per_cloud} "
          f"clients, d={d_params:,}, full participation, hierarchical):")
    print(f"{'policy':26s}{'intra MB':>10s}{'cross MB':>10s}"
          f"{'$/round':>10s}{'cross vs fp32':>15s}")
    print("-" * 71)
    base_cross = None
    for name, lp in policies:
        client, edge = lp.payload_vectors(topo, d_params)
        b = cm.bytes_per_round(topo, sel, d_params, client_payload=client,
                               edge_payload=edge)
        dollars = cm.round_cost(topo, sel, d_params, client_payload=client,
                                edge_payload=edge)
        base_cross = base_cross if base_cross is not None else b["cross"]
        print(f"{name:26s}{b['intra'] / MB:10.2f}{b['cross'] / MB:10.2f}"
              f"{dollars:10.6f}{base_cross / max(b['cross'], 1):14.2f}x")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--steps-per-round", type=int, default=1,
                    help="train steps per FL round (local epochs)")
    args = ap.parse_args()
    cm = CostModel()

    rows = []
    for p in sorted(glob.glob(os.path.join(args.dir, "*pod2*.json"))):
        r = json.load(open(p))
        if r.get("status") != "ok":
            continue
        cross = r.get("cross_pod_bytes_per_device", 0) * r.get("chips", 0) / 2
        intra = (r.get("collective_bytes_per_device", 0) * r.get("chips", 0)
                 - cross)
        dollars = cm.collective_egress_dollars(int(cross))
        rows.append((r["arch"], r["shape"], cross / GB, intra / GB, dollars))

    print(f"{'arch':28s}{'shape':14s}{'cross-pod GB':>14s}"
          f"{'intra GB':>12s}{'egress $/step':>15s}")
    print("-" * 83)
    total = 0.0
    for arch, shape, cgb, igb, d in rows:
        total += d
        print(f"{arch:28s}{shape:14s}{cgb:14.2f}{igb:12.1f}{d:15.4f}")
    print("-" * 83)
    print(f"{'(1 round = %d step(s))' % args.steps_per_round:56s}"
          f"{'total':>12s}{total * args.steps_per_round:15.4f}")
    print("\nInterpretation: the hierarchical two_phase step keeps the "
          "full-gradient all-reduce INSIDE each pod; only the K cloud "
          "aggregates cross the pod boundary (Eq. 5-6) — compare "
          "cross-pod vs intra columns.")

    fl_breakdown()


if __name__ == "__main__":
    main()

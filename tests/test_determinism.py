"""Bit-identical rerun guarantees: same (FLConfig, method, seed) ⇒ the
same SimResult, across fresh data builds and fresh servers. This is what
lets the scenario matrix serve as a *regression* suite — any hidden
global RNG (or nondeterministic hook) in the round loop breaks it."""
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.federated import make_data, run_simulation

pytestmark = pytest.mark.slow

_FL = dict(n_clouds=3, clients_per_cloud=4, clients_per_round=6,
           local_epochs=1, local_batch=8, ref_samples=16,
           attack="sign_flip", malicious_frac=0.3, attack_scale=1.0)


def _run(method: str, compressor: str, scenario=None):
    fl = FLConfig(compressor=compressor, compress_ratio=0.25,
                  link_policy="cross_only", **_FL)
    # data is rebuilt from scratch each call on purpose: the guarantee
    # covers the full pipeline, not one shared FederatedData object
    data = make_data(fl, "cifar10", seed=0, n_samples=600,
                     samples_per_client=16)
    return run_simulation(fl, method=method, scenario=scenario, rounds=3,
                          eval_every=1, data=data, seed=0)


def _assert_identical(a, b):
    assert a.accuracy == b.accuracy                 # bit-identical floats
    assert a.total_cost == b.total_cost
    assert a.intra_bytes == b.intra_bytes
    assert a.cross_bytes == b.cross_bytes
    assert np.array_equal(a.reputation, b.reputation)
    assert np.array_equal(a.malicious, b.malicious)


@pytest.mark.parametrize("compressor", ["none", "topk"])
@pytest.mark.parametrize("method", ["cost_trustfl", "fedavg"])
def test_rerun_is_bit_identical(method, compressor):
    _assert_identical(_run(method, compressor), _run(method, compressor))


@pytest.mark.parametrize("scenario", ["dropout", "price_surge",
                                      "intermittent", "alie"])
def test_scenario_hooks_are_deterministic(scenario):
    """Hooked rounds (delivery RNG, per-round pricing, gated malice,
    honest-statistics attacks) must also replay bit-identically."""
    a = _run("cost_trustfl", "none", scenario=scenario)
    b = _run("cost_trustfl", "none", scenario=scenario)
    assert a.scenario == b.scenario == scenario
    _assert_identical(a, b)

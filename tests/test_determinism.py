"""Bit-identical rerun guarantees: same (FLConfig, method, seed) ⇒ the
same SimResult, across fresh data builds and fresh servers. This is what
lets the scenario matrix serve as a *regression* suite — any hidden
global RNG (or nondeterministic hook) in the round loop breaks it.

Also the engine/legacy parity contract: the ``lax.scan`` round engine
(``run_simulation_batch``) must produce bit-identical per-round metrics,
reputation and final params to the per-round host loop (engine-backed
``FLServer``) for every method."""
import jax
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.configs.base import FLConfig
from repro.federated import (FLServer, make_data, make_topology,
                             run_simulation, run_simulation_batch,
                             run_simulation_sharded)
from repro.federated import engine as engine_mod
from repro.scenarios import get_scenario

pytestmark = pytest.mark.slow

_FL = dict(n_clouds=3, clients_per_cloud=4, clients_per_round=6,
           local_epochs=1, local_batch=8, ref_samples=16,
           attack="sign_flip", malicious_frac=0.3, attack_scale=1.0)


def _run(method: str, compressor: str, scenario=None):
    fl = FLConfig(compressor=compressor, compress_ratio=0.25,
                  link_policy="cross_only", **_FL)
    # data is rebuilt from scratch each call on purpose: the guarantee
    # covers the full pipeline, not one shared FederatedData object
    data = make_data(fl, "cifar10", seed=0, n_samples=600,
                     samples_per_client=16)
    return run_simulation(fl, method=method, scenario=scenario, rounds=3,
                          eval_every=1, data=data, seed=0)


def _assert_identical(a, b):
    assert a.accuracy == b.accuracy                 # bit-identical floats
    assert a.total_cost == b.total_cost
    assert a.intra_bytes == b.intra_bytes
    assert a.cross_bytes == b.cross_bytes
    assert np.array_equal(a.reputation, b.reputation)
    assert np.array_equal(a.malicious, b.malicious)


@pytest.mark.parametrize("compressor", ["none", "topk"])
@pytest.mark.parametrize("method", ["cost_trustfl", "fedavg"])
def test_rerun_is_bit_identical(method, compressor):
    _assert_identical(_run(method, compressor), _run(method, compressor))


@pytest.mark.parametrize("scenario", ["dropout", "price_surge",
                                      "intermittent", "alie"])
def test_scenario_hooks_are_deterministic(scenario):
    """Hooked rounds (delivery RNG, per-round pricing, gated malice,
    honest-statistics attacks) must also replay bit-identically."""
    a = _run("cost_trustfl", "none", scenario=scenario)
    b = _run("cost_trustfl", "none", scenario=scenario)
    assert a.scenario == b.scenario == scenario
    _assert_identical(a, b)


# -- engine (lax.scan) vs. host loop parity -----------------------------------

_METHODS = ("cost_trustfl", "fedavg", "krum", "trimmed_mean", "median",
            "fltrust")


def _batch(method: str, compressor: str, scenario=None):
    fl = FLConfig(compressor=compressor, compress_ratio=0.25,
                  link_policy="cross_only", **_FL)
    data = make_data(fl, "cifar10", seed=0, n_samples=600,
                     samples_per_client=16)
    return run_simulation_batch(fl, seeds=[0], method=method,
                                scenario=scenario, rounds=3, data=data)[0]


@pytest.mark.parametrize("method", _METHODS)
def test_engine_scan_matches_host_loop(method):
    """The scanned engine and the per-round host-driven loop (the
    engine-backed ``FLServer.run_round`` — run_simulation's default
    driver) are the SAME traced computation driven two ways — costs,
    bytes, reputation, delivery masks and final accuracy (⇒ final
    params) must agree bit-for-bit for every method. (The pre-engine
    legacy loop follows a different numpy RNG path and is covered by the
    determinism + cross-validation tests below.)"""
    loop = _run(method, "none")
    scan = _batch(method, "none")
    assert loop.final_accuracy == scan.final_accuracy
    _assert_identical_totals(loop, scan)


@pytest.mark.parametrize("scenario", ["dropout", "price_surge",
                                      "intermittent"])
def test_engine_scan_matches_host_loop_with_jit_hooks(scenario):
    """Jittable environment scenarios (delivery masks, gated malice,
    price schedules as data) keep the parity contract."""
    loop = _run("cost_trustfl", "none", scenario=scenario)
    scan = _batch("cost_trustfl", "none", scenario=scenario)
    assert loop.final_accuracy == scan.final_accuracy
    _assert_identical_totals(loop, scan)


def test_engine_scan_matches_host_loop_compressed():
    """EF residuals carried in RoundState replay the host driver's
    mutable-buffer bookkeeping exactly."""
    loop = _run("cost_trustfl", "topk")
    scan = _batch("cost_trustfl", "topk")
    assert loop.final_accuracy == scan.final_accuracy
    _assert_identical_totals(loop, scan)


def _assert_identical_totals(a, b):
    assert a.total_cost == b.total_cost
    assert a.intra_bytes == b.intra_bytes
    assert a.cross_bytes == b.cross_bytes
    assert np.array_equal(a.reputation, b.reputation)
    assert np.array_equal(a.malicious, b.malicious)


def test_engine_step_equals_scan_per_round():
    """Driver-level contract: T jitted step calls == one length-T scan,
    per-round metrics AND final state bit-identical."""
    fl = FLConfig(**_FL)
    topo = make_topology(fl)
    data = make_data(fl, "cifar10", seed=0, n_samples=600,
                     samples_per_client=16)
    static = engine_mod.static_from(fl, topo, "cost_trustfl",
                                    input_shape=data.client_x.shape[2:],
                                    n_classes=data.n_classes)
    eng = engine_mod.compiled(static)
    dev = engine_mod.make_client_data(fl, topo, data, seed=0)

    state = eng.init_state(0)
    outs = []
    for t in range(3):
        state, out = eng.step(state, dev, t)
        outs.append(out)
    fin, scan_outs = eng.run(eng.init_state(0), dev, 3)

    for leaf_a, leaf_b in zip(jax.tree.leaves(state), jax.tree.leaves(fin)):
        assert np.array_equal(np.asarray(leaf_a), np.asarray(leaf_b))
    for t, out in enumerate(outs):
        for name in out._fields:
            assert np.array_equal(
                np.asarray(getattr(out, name)),
                np.asarray(getattr(scan_outs, name))[t]), (t, name)


def test_engine_compact_aggregation_matches_core_reference():
    """Cross-validation of the engine's compact m-row Eq. 5–13 pipeline
    against the reference (N, D) implementation in
    ``core.cost_trustfl_aggregate`` (still exercised by the legacy host
    loop): force BOTH drivers onto the engine's selected set for one
    round and require params + reputation to agree to float tolerance
    (bitwise equality is not expected — the reductions associate
    differently)."""
    from repro.federated.server import FLServer

    fl = FLConfig(**_FL)
    topo = make_topology(fl)
    data = make_data(fl, "cifar10", seed=0, n_samples=600,
                     samples_per_client=16)
    eng_srv = FLServer(fl, topo, data, method="cost_trustfl", seed=0,
                       engine="jit")
    m0 = eng_srv.run_round(0)
    sel_mask = np.asarray(m0.selected)

    host_srv = FLServer(fl, topo, data, method="cost_trustfl", seed=0,
                        engine="host")
    host_srv._select = lambda rng: sel_mask
    host_srv.run_round(0)

    for k in host_srv.params:
        np.testing.assert_allclose(np.asarray(host_srv.params[k]),
                                   np.asarray(eng_srv.params[k]),
                                   rtol=1e-5, atol=1e-6, err_msg=k)
    np.testing.assert_allclose(np.asarray(host_srv.rep.ema),
                               np.asarray(eng_srv.rep.ema),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("method,compressor", [("cost_trustfl", "topk"),
                                               ("fedavg", "none")])
def test_legacy_host_loop_is_deterministic(method, compressor):
    """The pre-engine host loop (``engine="host"``) stays the reference
    driver for host-hook scenarios — keep it covered: reruns must be
    bit-identical and its metrics finite."""
    from repro.federated.server import FLServer

    fl = FLConfig(compressor=compressor, compress_ratio=0.25,
                  link_policy="cross_only", **_FL)
    topo = make_topology(fl)
    data = make_data(fl, "cifar10", seed=0, n_samples=600,
                     samples_per_client=16)

    def run_host():
        s = FLServer(fl, topo, data, method=method, seed=0, engine="host")
        assert s._eng is None
        for t in range(2):
            s.run_round(t)
        return s

    a, b = run_host(), run_host()
    assert a.cum_cost == b.cum_cost and np.isfinite(a.cum_cost)
    assert a.cum_intra_bytes == b.cum_intra_bytes
    assert a.cum_cross_bytes == b.cum_cross_bytes
    for ma, mb in zip(a.history, b.history):
        assert np.array_equal(ma.selected, mb.selected)
        assert np.array_equal(ma.reputation, mb.reputation)
    for k in a.params:
        assert np.array_equal(np.asarray(a.params[k]),
                              np.asarray(b.params[k]))


# -- property-based cross-engine parity fuzz ----------------------------------
#
# Draws over the scenario × method × compressor × selected_count space and
# asserts the three-way engine contract on every drawn configuration:
#
# * per-round jit driver vs lax.scan driver — bit-exact (same traced
#   computation driven two ways);
# * legacy host loop with the jit driver's selection masks replayed —
#   byte-exact $/bytes, params/reputation to fp tolerance (the compact
#   m-row aggregation vs the (N, D) reference associate differently);
# * sharded engine on a 1×1 mesh — masks/$ exact, reputation/accuracy
#   to fp tolerance.
#
# The space deliberately excludes host-RNG scenarios (dropout draws
# delivery from numpy on the host path — replaying selection is not
# enough) and matrix-shaped randomness (gaussian / min_max), which the
# sharded engine refuses by design; those exclusions are the routing
# tests' responsibility. qsgd is IN the pool: its rounding noise is
# keyed per sender (fold_in(client_id)), so it is engine-invariant.

_FUZZ_BASE = dict(n_clouds=3, clients_per_cloud=4, local_epochs=1,
                  local_batch=8, ref_samples=16, attack="sign_flip",
                  malicious_frac=0.3, attack_scale=1.0)
_FUZZ_TOL = dict(rtol=1e-4, atol=1e-6)
_FUZZ_ROUNDS = 2
_fuzz_data_cache = {}


def _fuzz_data():
    # one dataset for the whole fuzz — cross-ENGINE parity is the
    # property under test; pipeline determinism has its own tests above
    if "d" not in _fuzz_data_cache:
        fl = FLConfig(clients_per_round=6, **_FUZZ_BASE)
        _fuzz_data_cache["d"] = make_data(fl, "cifar10", seed=0,
                                          n_samples=400,
                                          samples_per_client=8)
    return _fuzz_data_cache["d"]


@settings(max_examples=8, deadline=None, derandomize=True)
@given(method=st.sampled_from(_METHODS),
       compressor=st.sampled_from(("none", "topk", "qsgd")),
       scenario=st.sampled_from((None, "price_surge", "alie", "alie_norm",
                                 "alie_sleeper")),
       trust_features=st.sampled_from(("scalar", "multi")),
       clients_per_round=st.sampled_from((4, 6)))
def test_cross_engine_parity_fuzz(method, compressor, scenario,
                                  trust_features, clients_per_round):
    if trust_features == "multi" and method != "cost_trustfl":
        trust_features = "scalar"     # the gate only exists on Eq. 7
    fl = FLConfig(clients_per_round=clients_per_round,
                  compressor=compressor, compress_ratio=0.25,
                  link_policy="cross_only", trust_features=trust_features,
                  **_FUZZ_BASE)
    sc = get_scenario(scenario) if scenario else None
    if sc is not None:
        fl = sc.apply(fl)
    data = _fuzz_data()
    topo = make_topology(fl)

    jit_srv = FLServer(fl, topo, data, method=method, seed=0, scenario=sc,
                       engine="jit")
    masks = [np.asarray(jit_srv.run_round(t).selected)
             for t in range(_FUZZ_ROUNDS)]
    jit_rep = np.array(jit_srv.rep.ema)

    # scan driver: the same traced computation, bit-exact
    scan = run_simulation_batch(fl, seeds=[0], method=method, scenario=sc,
                                rounds=_FUZZ_ROUNDS, data=data)[0]
    assert scan.total_cost == jit_srv.cum_cost
    assert scan.intra_bytes == jit_srv.cum_intra_bytes
    assert scan.cross_bytes == jit_srv.cum_cross_bytes
    assert np.array_equal(scan.reputation, jit_rep)

    # host loop, selection replayed from the jit driver
    host_srv = FLServer(fl, topo, data, method=method, seed=0, scenario=sc,
                        engine="host")
    replay = iter(masks)
    host_srv._select = lambda rng: next(replay)
    for t in range(_FUZZ_ROUNDS):
        host_srv.run_round(t)
    assert host_srv.cum_cost == jit_srv.cum_cost
    assert host_srv.cum_intra_bytes == jit_srv.cum_intra_bytes
    assert host_srv.cum_cross_bytes == jit_srv.cum_cross_bytes
    np.testing.assert_allclose(np.array(host_srv.rep.ema), jit_rep,
                               **_FUZZ_TOL)
    for k in host_srv.params:
        np.testing.assert_allclose(np.asarray(host_srv.params[k]),
                                   np.asarray(jit_srv.params[k]),
                                   err_msg=k, **_FUZZ_TOL)

    # sharded engine on a 1×1 mesh
    shard = run_simulation_sharded(fl, method=method, scenario=sc,
                                   rounds=_FUZZ_ROUNDS, data=data, seed=0,
                                   n_devices=1)
    assert shard.total_cost == jit_srv.cum_cost
    assert shard.intra_bytes == jit_srv.cum_intra_bytes
    assert shard.cross_bytes == jit_srv.cum_cross_bytes
    np.testing.assert_allclose(shard.reputation, jit_rep, **_FUZZ_TOL)


def test_vmapped_batch_is_deterministic_and_seedwise_consistent():
    """vmap over seeds: rerunning the batch is bit-identical, and each
    row tracks its own single-seed scan (allclose — vmap may reassociate
    float reductions, so bitwise equality is only promised for the
    unbatched drivers)."""
    fl = FLConfig(**_FL)
    a = run_simulation_batch(fl, seeds=[0, 1], method="cost_trustfl",
                             rounds=3)
    b = run_simulation_batch(fl, seeds=[0, 1], method="cost_trustfl",
                             rounds=3)
    for ra, rb in zip(a, b):
        assert ra.total_cost == rb.total_cost
        assert np.array_equal(ra.reputation, rb.reputation)
    singles = [run_simulation_batch(fl, seeds=[s], method="cost_trustfl",
                                    rounds=3)[0] for s in (0, 1)]
    for row, single in zip(a, singles):
        assert row.total_cost == single.total_cost   # host f64 accounting
        assert np.array_equal(row.malicious, single.malicious)
        np.testing.assert_allclose(row.reputation, single.reputation,
                                   rtol=1e-5, atol=1e-6)

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (hypothesis) +
directed cases. Kernels run in interpret mode (CPU container; TPU is the
compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.kernels import ops, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@given(n=st.integers(2, 17), d=st.integers(3, 300),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
       seed=st.integers(0, 5))
def test_trust_score_matches_ref(n, d, dtype, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    g = _rand(k1, (n, d), dtype)
    r = _rand(k2, (d,), dtype)
    rep = jax.random.uniform(k3, (n,))
    phi, ts, norms = ops.trust_score(g, r, rep, block_n=4, block_d=128)
    phi_r, ts_r, norms_r = ref.trust_score_ref(g, r, rep)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(phi, phi_r, rtol=tol, atol=tol)
    np.testing.assert_allclose(ts, ts_r, rtol=tol, atol=tol)
    np.testing.assert_allclose(norms, norms_r, rtol=tol, atol=tol)


@given(n=st.integers(2, 12), d=st.integers(2, 260),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
       seed=st.integers(0, 5))
def test_weighted_agg_matches_ref(n, d, dtype, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    g = _rand(k1, (n, d), dtype)
    ts = jax.random.uniform(k2, (n,)) + 0.1
    norms = jnp.linalg.norm(g.astype(jnp.float32), axis=1)
    ref_norm = jnp.asarray(1.7)
    out = ops.weighted_agg(g, ts, norms, ref_norm, block_d=64)
    out_r = ref.weighted_agg_ref(g, ts, norms, ref_norm)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(out, out_r, rtol=tol, atol=tol)


@given(b=st.integers(1, 5), t=st.integers(1, 70), d=st.integers(1, 40),
       seed=st.integers(0, 5))
def test_linear_scan_matches_ref(b, t, d, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = jax.random.uniform(k1, (b, t, d), minval=0.1, maxval=0.99)
    x = jax.random.normal(k2, (b, t, d))
    out = ops.linear_scan(a, x, chunk=16, block_b=2)
    out_r = ref.linear_scan_ref(a, x)
    np.testing.assert_allclose(out, out_r, rtol=2e-5, atol=2e-5)


def test_linear_scan_is_true_recurrence():
    """Directed: compare against an explicit python loop."""
    rng = np.random.default_rng(0)
    a = rng.uniform(0.5, 0.95, (2, 9, 3)).astype(np.float32)
    b = rng.normal(size=(2, 9, 3)).astype(np.float32)
    h = np.zeros((2, 3), np.float32)
    expect = np.zeros_like(b)
    for t in range(9):
        h = a[:, t] * h + b[:, t]
        expect[:, t] = h
    out = ops.linear_scan(jnp.asarray(a), jnp.asarray(b), chunk=4)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-6)


# shapes drawn from a small pool so interpret-mode retraces are bounded
@given(n=st.sampled_from([1, 3, 8]), d=st.sampled_from([4, 129, 300]),
       seed=st.integers(0, 5))
def test_topk_mask_matches_ref(n, d, seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    k = max(1, d // 7)
    out = ops.topk_mask(g, k=k, block_n=4, block_d=128)
    thr = jax.lax.top_k(jnp.abs(g), k)[0][:, -1]
    out_r = ref.topk_mask_ref(g, thr)
    np.testing.assert_allclose(out, out_r, rtol=1e-5, atol=1e-5)
    # exactly k survivors per row (ties have measure zero for normals)
    assert int((np.array(out) != 0).sum(axis=1).max()) == min(k, d)


@given(n=st.sampled_from([1, 5]), d=st.sampled_from([6, 200]),
       levels=st.sampled_from([1, 15, 127]), seed=st.integers(0, 5))
def test_stochastic_quantize_matches_ref(n, d, levels, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (n, d))
    u = jax.random.uniform(k2, (n, d))
    scale = jnp.max(jnp.abs(x), axis=1)
    q = ops.stochastic_quantize(x, scale, u, levels=levels, block_n=4,
                                block_d=128)
    q_r = ref.stochastic_quantize_ref(x, scale, u, levels)
    np.testing.assert_allclose(np.array(q), np.array(q_r), atol=1e-5)
    assert int(jnp.abs(q).max()) <= levels
    # dequantized error is bounded by one quantization step
    err = jnp.abs(ref.dequantize_ref(q, scale, levels) - x)
    assert float(err.max()) <= float(scale.max()) / levels + 1e-5


def test_trust_score_agrees_with_core_shapley():
    """The kernel's phi equals repro.core.shapley.gradient_contribution."""
    from repro.core import gradient_contribution
    key = jax.random.PRNGKey(3)
    g = jax.random.normal(key, (8, 96))
    phi_k, _, _ = ops.trust_score(g, jnp.ones(96), jnp.ones(8) / 8)
    phi_c = gradient_contribution(g)
    np.testing.assert_allclose(phi_k, phi_c, rtol=1e-5, atol=1e-5)


def test_rglru_kernel_path_matches_xla_path():
    """rglru_forward(use_kernel=True) == associative-scan reference."""
    from dataclasses import replace
    from repro.configs import get_arch, reduced
    from repro.models.rglru import init_rglru, rglru_forward
    cfg = reduced(get_arch("recurrentgemma-2b"), d_model=64, layers=1)
    params = init_rglru(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 64))
    y_xla = rglru_forward(params, x, cfg, use_kernel=False)
    y_pl = rglru_forward(params, x, cfg, use_kernel=True)
    np.testing.assert_allclose(np.array(y_xla), np.array(y_pl),
                               rtol=2e-4, atol=2e-5)

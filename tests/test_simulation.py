"""Integration: the paper's end-to-end claims at reduced scale —
Cost-TrustFL beats FedAvg under attack, costs less, and identifies
malicious clients via reputation. (Rounds are reduced for CPU; trends,
not absolute numbers, are asserted — see DESIGN.md §2.2.)"""
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import CloudTopology, CostModel
from repro.federated import make_data, run_simulation

# end-to-end simulations: excluded from the fast CI job (-m "not slow")
pytestmark = pytest.mark.slow

ROUNDS = 6
_FL = dict(n_clouds=3, clients_per_cloud=6, clients_per_round=9,
           local_epochs=1, local_batch=16, ref_samples=32)


@pytest.fixture(scope="module")
def sim_data():
    fl = FLConfig(**_FL)
    return make_data(fl, "cifar10", seed=0, n_samples=4000,
                     samples_per_client=48)


# At this toy scale the per-seed ours-vs-fedavg margin is dominated by
# which clients the cost-aware policy locks onto, so the Table I trend
# is asserted over a small seed set rather than one pinned trajectory
# (a single seed can be re-pinned to mask a real defense regression).
_TREND_SEEDS = (1, 5, 6)


@pytest.fixture(scope="module")
def label_flip_runs(sim_data):
    fl = FLConfig(attack="label_flip", malicious_frac=0.3, **_FL)
    ours = [run_simulation(fl, method="cost_trustfl", rounds=ROUNDS,
                           eval_every=ROUNDS, data=sim_data, seed=s)
            for s in _TREND_SEEDS]
    fedavg = [run_simulation(fl, method="fedavg", rounds=ROUNDS,
                             eval_every=ROUNDS, data=sim_data, seed=s)
              for s in _TREND_SEEDS]
    return ours, fedavg


def test_runs_produce_finite_accuracy(label_flip_runs):
    for r in [*label_flip_runs[0], *label_flip_runs[1]]:
        assert 0.0 <= r.final_accuracy <= 1.0


def test_cost_trustfl_cheaper_than_fedavg(label_flip_runs):
    """Fig. 3 claim: hierarchical + cost-aware selection reduces $ cost
    (structural — holds at every seed)."""
    ours, fedavg = label_flip_runs
    for o, f in zip(ours, fedavg):
        assert o.total_cost < f.total_cost


def test_cost_trustfl_not_worse_under_attack(label_flip_runs):
    """Table I trend (relaxed for 6 CPU rounds): mean accuracy margin
    over the seed set >= -eps."""
    ours, fedavg = label_flip_runs
    margin = (np.mean([o.final_accuracy for o in ours])
              - np.mean([f.final_accuracy for f in fedavg]))
    assert margin >= -0.05


def test_reputation_separates_malicious(sim_data):
    """Sign-flipping attackers end with below-average reputation."""
    fl = FLConfig(attack="sign_flip", malicious_frac=0.3, **_FL)
    r = run_simulation(fl, method="cost_trustfl", rounds=ROUNDS,
                       eval_every=ROUNDS, data=sim_data, seed=0)
    rep, mal = r.reputation, r.malicious
    # only selected clients get scored; compare mean reputations
    assert rep[mal].mean() <= rep[~mal].mean() + 1e-9


def test_no_attack_all_methods_run(sim_data):
    fl = FLConfig(attack="none", malicious_frac=0.0, **_FL)
    for m in ("krum", "trimmed_mean", "median", "fltrust"):
        r = run_simulation(fl, method=m, rounds=2, eval_every=2,
                           data=sim_data, seed=0)
        assert 0.0 <= r.final_accuracy <= 1.0


def test_hierarchical_cost_structure(sim_data):
    """Cost accounting: Cost-TrustFL pays K cross-cloud uploads per round,
    FedAvg pays one per selected remote client (Eq. 1 vs Eq. 3)."""
    fl = FLConfig(attack="none", **_FL)
    topo = CloudTopology.even(fl.n_clouds, fl.clients_per_cloud)
    cm = CostModel(fl.c_intra, fl.c_cross)
    sel = np.ones(topo.n_clients, bool)
    d = 1_000_000
    hier = cm.round_cost(topo, sel, d, hierarchical=True)
    flat = cm.round_cost(topo, sel, d, hierarchical=False)
    assert hier < flat

"""Pure-jnp algebra of the adaptive update-level attacks (ALIE / IPM /
min-max / collusion) and the UPDATE_ATTACKS registry dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ATTACKS, UPDATE_ATTACKS, alie_attack,
                        apply_update_attack, collusion_attack, ipm_attack,
                        min_max_attack, register_update_attack)
from repro.core.attacks import _honest_moments


def _updates(n=12, d=24, n_mal=4, seed=0, spread=0.3):
    rng = np.random.default_rng(seed)
    base = rng.normal(size=d)
    u = jnp.asarray(base + spread * rng.normal(size=(n, d)), jnp.float32)
    mal = jnp.zeros(n, bool).at[:n_mal].set(True)
    return u, mal


def _honest_np(u, mal):
    h = np.array(u)[~np.array(mal)]
    return h, h.mean(0), h.std(0)


def test_alie_rows_inside_honest_envelope():
    u, mal = _updates()
    z = 1.5
    out = np.array(alie_attack(u, mal, z=z))
    h, mean, std = _honest_np(u, mal)
    m = np.array(mal)
    # malicious rows lie within mean ± z·std of the honest rows ...
    assert (np.abs(out[m] - mean) <= z * std + 1e-4).all()
    # ... at exactly mean − z·std, identical across colluders
    assert np.allclose(out[m], mean - z * std, atol=1e-4)
    assert (out[m] == out[m][0]).all()
    # honest rows untouched
    assert np.array_equal(out[~m], np.array(u)[~m])


def test_alie_z_scales_the_deviation():
    u, mal = _updates()
    _, mean, _ = _honest_np(u, mal)
    d1 = np.abs(np.array(alie_attack(u, mal, z=1.0))[0] - mean)
    d2 = np.abs(np.array(alie_attack(u, mal, z=2.0))[0] - mean)
    assert (d2 >= d1 - 1e-6).all() and d2.sum() > d1.sum()


def test_ipm_antialigned_with_honest_mean():
    u, mal = _updates()
    eps = 2.0
    out = np.array(ipm_attack(u, mal, scale=eps))
    h, mean, _ = _honest_np(u, mal)
    m = np.array(mal)
    assert np.allclose(out[m], -eps * mean, atol=1e-5)
    # negative inner product with the honest direction
    assert (out[m] @ mean < 0).all()
    assert np.array_equal(out[~m], np.array(u)[~m])


def test_min_max_respects_distance_envelope():
    u, mal = _updates(spread=0.5)
    out = np.array(min_max_attack(u, mal))
    h, mean, _ = _honest_np(u, mal)
    m = np.array(mal)
    d_max = max(np.linalg.norm(a - b) for a in h for b in h)
    # every malicious row within the max honest pairwise distance of
    # every honest row (the evasion constraint) ...
    dists = np.linalg.norm(h[None, :, :] - out[m][:, None, :], axis=-1)
    assert (dists <= d_max * (1 + 1e-4) + 1e-5).all()
    # ... but strictly displaced from the honest mean (γ > 0), jointly
    assert (out[m] == out[m][0]).all()
    assert np.linalg.norm(out[m][0] - mean) > 1e-3
    # displacement is along −mean (harmful direction)
    assert (out[m][0] - mean) @ mean < 0
    assert np.array_equal(out[~m], np.array(u)[~m])


def test_collusion_rows_identical_and_harmful():
    u, mal = _updates()
    scale = 1.5
    out = np.array(collusion_attack(u, mal, scale=scale))
    m = np.array(mal)
    mal_mean = np.array(u)[m].mean(0)
    assert np.allclose(out[m], -scale * mal_mean, atol=1e-5)
    assert (out[m] == out[m][0]).all()
    assert np.array_equal(out[~m], np.array(u)[~m])


def test_honest_moments_masked():
    u, mal = _updates()
    mean, std = map(np.array, _honest_moments(u, mal))
    _, mean_np, std_np = _honest_np(u, mal)
    assert np.allclose(mean, mean_np, atol=1e-5)
    assert np.allclose(std, std_np, atol=1e-4)


@pytest.mark.parametrize("name", ATTACKS)
def test_no_malicious_is_identity(name):
    u, _ = _updates()
    none = jnp.zeros(u.shape[0], bool)
    out = apply_update_attack(name, u, none, jax.random.PRNGKey(0))
    assert np.array_equal(np.array(out), np.array(u))


@pytest.mark.parametrize("name", ATTACKS)
def test_all_malicious_stays_finite(name):
    """Degenerate masks (no honest rows to take statistics from) must not
    produce NaN/inf — the scenario matrix hits small selected sets."""
    u, _ = _updates()
    allm = jnp.ones(u.shape[0], bool)
    out = apply_update_attack(name, u, allm, jax.random.PRNGKey(0))
    assert bool(jnp.isfinite(out).all())


@pytest.mark.parametrize("name", ATTACKS)
def test_registry_dispatch_is_jittable(name):
    u, mal = _updates()
    f = jax.jit(lambda u, m, k: apply_update_attack(
        name, u, m, k, sigma=0.5, scale=2.0, z=1.0))
    out = f(u, mal, jax.random.PRNGKey(1))
    assert out.shape == u.shape and bool(jnp.isfinite(out).all())


def test_register_update_attack_extends_dispatch():
    try:
        register_update_attack(
            "zero_out", lambda u, m, k, *, sigma, scale, z:
            jnp.where(m[:, None], jnp.zeros_like(u), u))
        u, mal = _updates()
        out = np.array(apply_update_attack("zero_out", u, mal,
                                           jax.random.PRNGKey(0)))
        assert (out[np.array(mal)] == 0).all()
    finally:
        UPDATE_ATTACKS.pop("zero_out", None)

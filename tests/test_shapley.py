"""Shapley estimators: exact enumeration vs the paper's gradient-based
O(N) score (Fig. 5b correlation claim) and MC sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (cosine_utility, exact_shapley, gradient_contribution,
                        monte_carlo_shapley)


def _toy_gradients(n=10, d=32, n_malicious=3, seed=0):
    rng = np.random.default_rng(seed)
    ref = rng.normal(size=d)
    g = 0.8 * ref + 0.5 * rng.normal(size=(n, d))
    g[:n_malicious] = -g[:n_malicious]          # sign-flipped attackers
    return g.astype(np.float32), ref.astype(np.float32)


def test_exact_shapley_efficiency_axiom():
    """Σ φ_i = v(N) − v(∅) (efficiency)."""
    g, ref = _toy_gradients(6)
    util = cosine_utility(g, ref)
    phi = exact_shapley(util, 6)
    full = util(np.ones(6, bool))
    assert np.isclose(phi.sum(), full, rtol=1e-6)


def test_exact_shapley_symmetry():
    """Identical clients get identical values."""
    g = np.ones((4, 8), np.float32)
    util = cosine_utility(g, np.ones(8, np.float32))
    phi = exact_shapley(util, 4)
    assert np.allclose(phi, phi[0])


def test_monte_carlo_matches_exact():
    g, ref = _toy_gradients(8)
    util = cosine_utility(g, ref)
    exact = exact_shapley(util, 8)
    mc = monte_carlo_shapley(util, 8, n_perms=400, seed=1)
    r = np.corrcoef(exact, mc)[0, 1]
    assert r > 0.99, f"MC correlation too low: {r}"


def test_gradient_score_correlates_with_exact_shapley():
    """The paper's Fig. 5b claim: gradient-based estimates correlate with
    true Shapley values (r = 0.962 in the paper)."""
    g, ref = _toy_gradients(10, n_malicious=3, seed=2)
    util = cosine_utility(g, ref)
    exact = exact_shapley(util, 10)
    phi = np.array(gradient_contribution(jnp.asarray(g)))
    r = np.corrcoef(exact, phi)[0, 1]
    assert r > 0.8, f"gradient score correlation too low: {r}"


def test_gradient_score_zero_for_opposed_clients():
    g, _ = _toy_gradients(10, n_malicious=3)
    phi = np.array(gradient_contribution(jnp.asarray(g)))
    # sign-flipped clients anti-align with the honest mean -> ReLU -> 0
    assert (phi[:3] < phi[3:].min()).all()
    assert (phi[:3] == 0).all()


def test_gradient_score_scale_sensitivity():
    """φ includes ‖g‖: doubling a benign client's gradient doubles φ."""
    rng = np.random.default_rng(0)
    base = rng.normal(size=(6, 16)).astype(np.float32)
    base[:] = np.abs(base)                       # all aligned-ish
    g2 = base.copy()
    g2[0] *= 2
    gbar = jnp.asarray(base.mean(0))
    p1 = gradient_contribution(jnp.asarray(base), gbar)
    p2 = gradient_contribution(jnp.asarray(g2), gbar)
    assert np.isclose(float(p2[0] / p1[0]), 2.0, rtol=1e-5)

"""Shapley estimators: exact enumeration vs the paper's gradient-based
O(N) score (Fig. 5b correlation claim) and MC sampling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (cosine_utility, exact_shapley, gradient_contribution,
                        monte_carlo_shapley)


def _toy_gradients(n=10, d=32, n_malicious=3, seed=0):
    rng = np.random.default_rng(seed)
    ref = rng.normal(size=d)
    g = 0.8 * ref + 0.5 * rng.normal(size=(n, d))
    g[:n_malicious] = -g[:n_malicious]          # sign-flipped attackers
    return g.astype(np.float32), ref.astype(np.float32)


def test_exact_shapley_efficiency_axiom():
    """Σ φ_i = v(N) − v(∅) (efficiency)."""
    g, ref = _toy_gradients(6)
    util = cosine_utility(g, ref)
    phi = exact_shapley(util, 6)
    full = util(np.ones(6, bool))
    assert np.isclose(phi.sum(), full, rtol=1e-6)


def test_exact_shapley_symmetry():
    """Identical clients get identical values."""
    g = np.ones((4, 8), np.float32)
    util = cosine_utility(g, np.ones(8, np.float32))
    phi = exact_shapley(util, 4)
    assert np.allclose(phi, phi[0])


def test_monte_carlo_matches_exact():
    g, ref = _toy_gradients(8)
    util = cosine_utility(g, ref)
    exact = exact_shapley(util, 8)
    mc = monte_carlo_shapley(util, 8, n_perms=400, seed=1)
    r = np.corrcoef(exact, mc)[0, 1]
    assert r > 0.99, f"MC correlation too low: {r}"


def test_gradient_score_correlates_with_exact_shapley():
    """The paper's Fig. 5b claim: gradient-based estimates correlate with
    true Shapley values (r = 0.962 in the paper)."""
    g, ref = _toy_gradients(10, n_malicious=3, seed=2)
    util = cosine_utility(g, ref)
    exact = exact_shapley(util, 10)
    phi = np.array(gradient_contribution(jnp.asarray(g)))
    r = np.corrcoef(exact, phi)[0, 1]
    assert r > 0.8, f"gradient score correlation too low: {r}"


def test_gradient_score_zero_for_opposed_clients():
    g, _ = _toy_gradients(10, n_malicious=3)
    phi = np.array(gradient_contribution(jnp.asarray(g)))
    # sign-flipped clients anti-align with the honest mean -> ReLU -> 0
    assert (phi[:3] < phi[3:].min()).all()
    assert (phi[:3] == 0).all()


def test_fig5_correlation_pinned_threshold():
    """Fig. 5b validation: over a set of tiny-N synthetic coalitions the
    Pearson correlation between the paper's O(N) gradient score and the
    exact Shapley enumeration stays above a pinned threshold (the paper
    reports r = 0.962; the synthetic coalitions sit above it — pin both
    the per-seed floor and the mean so a regression in either the score
    or the utility shows up)."""
    rs = []
    for seed in range(6):
        g, ref = _toy_gradients(10, n_malicious=3, seed=seed)
        util = cosine_utility(g, ref)
        exact = exact_shapley(util, 10)
        phi = np.array(gradient_contribution(jnp.asarray(g)))
        rs.append(np.corrcoef(exact, phi)[0, 1])
    assert min(rs) > 0.95, f"per-seed correlation floor broken: {rs}"
    assert np.mean(rs) > 0.97, f"mean correlation regressed: {rs}"


def test_monte_carlo_shapley_deterministic_under_fixed_seed():
    """Permutation sampling is driven by its own Generator: the same
    seed must replay bit-identically (the Fig. 5 timing benchmark and
    the correlation claims depend on it), different seeds must not."""
    g, ref = _toy_gradients(8)
    util = cosine_utility(g, ref)
    a = monte_carlo_shapley(util, 8, n_perms=100, seed=7)
    b = monte_carlo_shapley(util, 8, n_perms=100, seed=7)
    c = monte_carlo_shapley(util, 8, n_perms=100, seed=8)
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)


def test_gradient_score_scale_sensitivity():
    """φ includes ‖g‖: doubling a benign client's gradient doubles φ."""
    rng = np.random.default_rng(0)
    base = rng.normal(size=(6, 16)).astype(np.float32)
    base[:] = np.abs(base)                       # all aligned-ish
    g2 = base.copy()
    g2[0] *= 2
    gbar = jnp.asarray(base.mean(0))
    p1 = gradient_contribution(jnp.asarray(base), gbar)
    p2 = gradient_contribution(jnp.asarray(g2), gbar)
    assert np.isclose(float(p2[0] / p1[0]), 2.0, rtol=1e-5)

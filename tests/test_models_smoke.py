"""Per-architecture smoke tests (deliverable f): REDUCED variant of every
assigned family — one forward/train step on CPU, asserting output shapes
and no NaNs — plus decode-vs-forward consistency for the cache path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS
from repro.models import build_model
from repro.models import transformer as tfm
from repro.models.common import softcap

BATCH, SEQ = 2, 24


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, key):
    m = build_model(arch, smoke=True)
    cfg = m.cfg
    assert cfg.d_model <= 512 and cfg.num_layers <= 2
    assert cfg.n_experts <= 4
    params = m.init(key)
    batch = m.dummy_batch(key, batch=BATCH, seq=SEQ)

    # forward: hidden shape
    h, aux, off = tfm.forward_hidden(params, cfg, batch)
    text = SEQ - cfg.vis_tokens if cfg.vis_tokens else SEQ
    assert h.shape == (BATCH, text + off, cfg.d_model)
    assert np.isfinite(np.array(h, np.float32)).all()

    # one train step: loss + grads finite, params change
    (loss, metrics), grads = m.grad_fn()(params, batch)
    assert np.isfinite(float(loss))
    gsq = jax.tree.reduce(
        jnp.add, jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2),
                              grads))
    assert np.isfinite(float(gsq)) and float(gsq) > 0
    new = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    changed = jax.tree.reduce(
        jnp.add, jax.tree.map(lambda a, b: jnp.sum(jnp.abs(a - b)), params,
                              new))
    assert float(changed) > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a != "paligemma-3b"])
def test_smoke_decode_matches_forward(arch, key):
    """KV-cache/state decode equals the full-sequence forward."""
    m = build_model(arch, smoke=True)
    if m.cfg.n_experts:
        # capacity-based token dropping legitimately differs between
        # full-sequence and per-token routing; test the cache path in the
        # drop-free regime where decode must match exactly
        from dataclasses import replace
        from repro.models.model import Model
        m = Model(replace(m.cfg, capacity_factor=10.0))
    cfg = m.cfg
    params = m.init(key)
    batch = m.dummy_batch(key, batch=BATCH, seq=12)
    h, _, off = tfm.forward_hidden(params, cfg, batch)
    full = softcap(tfm.logits_fn(params, cfg, h[:, off:]), cfg.logit_softcap)
    last, _ = m.prefill(params, batch, max_len=12)
    np.testing.assert_allclose(np.array(last), np.array(full[:, -1]),
                               rtol=0.05, atol=5e-4)


def test_vlm_prefix_is_bidirectional(key):
    """PaliGemma: image-prefix tokens see each other; text stays causal."""
    m = build_model("paligemma-3b", smoke=True)
    cfg = m.cfg
    params = m.init(key)
    b = m.dummy_batch(key, batch=1, seq=16)
    h1, _, off = tfm.forward_hidden(params, cfg, b)
    # perturb the LAST patch: earlier-prefix outputs must change
    b2 = dict(b)
    b2["patches"] = b["patches"].at[:, -1].add(1.0)
    h2, _, _ = tfm.forward_hidden(params, cfg, b2)
    delta_first_patch = float(jnp.abs(h2[:, 0] - h1[:, 0]).max())
    assert delta_first_patch > 0, "prefix should attend bidirectionally"


def test_whisper_encoder_feeds_decoder(key):
    m = build_model("whisper-small", smoke=True)
    cfg = m.cfg
    params = m.init(key)
    b = m.dummy_batch(key, batch=1, seq=8)
    h1, _, _ = tfm.forward_hidden(params, cfg, b)
    b2 = dict(b)
    b2["frames"] = b["frames"] + 1.0
    h2, _, _ = tfm.forward_hidden(params, cfg, b2)
    assert float(jnp.abs(h2 - h1).max()) > 0, "cross-attention inactive"


def test_moe_router_balance_loss_positive(key):
    m = build_model("mixtral-8x7b", smoke=True)
    params = m.init(key)
    b = m.dummy_batch(key, batch=2, seq=16)
    _, metrics = m.loss(params, b)
    assert float(metrics["aux_loss"]) > 0


def test_sliding_window_blocks_long_range(key):
    """h2o-danube (SWA): token at position T is independent of tokens
    more than `window` positions back."""
    m = build_model("h2o-danube-3-4b", smoke=True)
    cfg = m.cfg                       # reduced window = 64 > seq 24 here,
    from dataclasses import replace   # shrink it to test the mask
    from repro.models.model import Model
    m = Model(replace(cfg, window=4))
    params = m.init(key)
    b = m.dummy_batch(key, batch=1, seq=20)
    h1, _, _ = tfm.forward_hidden(params, m.cfg, b)
    toks = b["tokens"].at[:, 0].set((b["tokens"][:, 0] + 7)
                                    % m.cfg.vocab_size)
    h2, _, _ = tfm.forward_hidden(params, m.cfg, {**b, "tokens": toks})
    # with 2 layers x window 4, receptive field ends well before pos 19
    assert float(jnp.abs(h2[:, -1] - h1[:, -1]).max()) < 1e-5


def test_rwkv_state_decode_is_constant_memory(key):
    """RWKV6 decode state does not grow with sequence length."""
    m = build_model("rwkv6-1.6b", smoke=True)
    params = m.init(key)
    c8 = m.init_cache(params, batch=1, max_len=8)
    c512 = m.init_cache(params, batch=1, max_len=512)
    n8 = sum(x.size for x in jax.tree.leaves(c8))
    n512 = sum(x.size for x in jax.tree.leaves(c512))
    assert n8 == n512

"""Scenario engine: registry invariants and hook units (fast), plus the
end-to-end scenario × method regression matrix (slow)."""
import dataclasses
from types import SimpleNamespace

import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core import CloudTopology, CostModel
from repro.core.attacks import UPDATE_ATTACKS
from repro.federated import make_data, run_simulation
from repro.scenarios import (LEVELS, Scenario, get_scenario, list_scenarios,
                             make_dropout_hook, make_intermittent_hook,
                             make_price_surge_hook, register_scenario)

_FL = dict(n_clouds=3, clients_per_cloud=4, clients_per_round=6,
           local_epochs=1, local_batch=8, ref_samples=16)


def _tiny_fl(**kw):
    return FLConfig(**{**_FL, **kw})


# -- registry invariants (fast) ------------------------------------------------

def test_registry_has_the_required_matrix():
    names = list_scenarios()
    assert len(names) >= 7
    assert len(list_scenarios("static")) >= 4
    assert (len(list_scenarios("adaptive"))
            + len(list_scenarios("environment"))) >= 3
    for n in names:
        assert get_scenario(n).level in LEVELS


def test_static_scenarios_cover_the_paper_attacks():
    static = set(list_scenarios("static"))
    assert {"label_flip", "gaussian", "sign_flip", "scaling"} <= static


def test_every_scenario_names_a_registered_attack():
    for n in list_scenarios():
        fl = get_scenario(n).apply(FLConfig())
        assert fl.attack in UPDATE_ATTACKS


def test_overrides_apply_is_idempotent():
    sc = get_scenario("alie")
    once = sc.apply(FLConfig())
    assert once.attack == "alie" and once.malicious_frac == 0.3
    assert sc.apply(once) == once


def test_sign_flip_scenario_pins_paper_scale():
    # paper semantics g ← −g, now that attack_scale is honored
    assert get_scenario("sign_flip").apply(FLConfig()).attack_scale == 1.0


def test_unknown_scenario_raises():
    with pytest.raises(KeyError):
        get_scenario("does_not_exist")


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError):
        register_scenario(Scenario(name="alie", level="adaptive"))


def test_bad_level_rejected():
    with pytest.raises(ValueError):
        Scenario(name="x", level="bogus")


# -- hook units (fast) ---------------------------------------------------------

def test_intermittent_hook_gates_malice_by_round():
    server = SimpleNamespace(malicious=np.array([True, False, True]))
    hook = make_intermittent_hook(warmup=3)
    for t in range(3):
        assert not hook(server, t).any()
    assert np.array_equal(hook(server, 3), server.malicious)


def test_dropout_hook_subsets_and_never_empties():
    hook = make_dropout_hook(p_drop=0.99)
    sel = np.ones(10, bool)
    out = hook(None, 0, np.random.default_rng(0), sel)
    assert out.any() and (sel | ~out).all()          # out ⊆ sel, non-empty
    # deterministic in the round rng
    again = hook(None, 0, np.random.default_rng(0), sel)
    assert np.array_equal(out, again)


def test_price_surge_hook_swaps_cost_model_and_unit_costs():
    fl = FLConfig()
    topo = CloudTopology.even(3, 4)
    cm = CostModel(fl.c_intra, fl.c_cross)
    server = SimpleNamespace(flcfg=fl, topo=topo, cost_model=cm,
                             unit_costs=cm.hierarchical_unit_costs(topo))
    before = server.unit_costs.copy()
    make_price_surge_hook((1.0, 2.0, 4.0, 2.0))(server, 2, None)
    assert server.cost_model.c_cross == pytest.approx(fl.c_cross * 4.0)
    assert server.cost_model.c_intra == fl.c_intra
    assert (server.unit_costs >= before).all() and \
        (server.unit_costs > before).any()


# -- FLConfig.aggregator wiring (fast-ish: rounds=0, no training) --------------

@pytest.fixture(scope="module")
def tiny_data():
    return make_data(_tiny_fl(), "cifar10", seed=0, n_samples=600,
                     samples_per_client=16)


def test_aggregator_field_is_the_method_default(tiny_data):
    fl = _tiny_fl(aggregator="fedavg")
    r = run_simulation(fl, rounds=0, data=tiny_data, seed=0)
    assert r.method == "fedavg"


def test_explicit_method_wins_over_aggregator_field(tiny_data):
    fl = _tiny_fl(aggregator="fedavg")
    r = run_simulation(fl, method="median", rounds=0, data=tiny_data, seed=0)
    assert r.method == "median"


def test_aggregator_default_is_cost_trustfl(tiny_data):
    r = run_simulation(_tiny_fl(), rounds=0, data=tiny_data, seed=0)
    assert r.method == "cost_trustfl"


# -- end-to-end regression matrix (slow) ---------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("method", ["cost_trustfl", "fedavg"])
@pytest.mark.parametrize("name", list_scenarios())
def test_scenario_matrix_smoke(name, method, tiny_data):
    """Every registered scenario × method survives two rounds with finite
    metrics — the mechanical enumeration the registry exists for."""
    r = run_simulation(_tiny_fl(), method=method, scenario=name, rounds=2,
                       eval_every=2, data=tiny_data, seed=0)
    assert r.scenario == name
    assert 0.0 <= r.final_accuracy <= 1.0
    assert np.isfinite(r.total_cost) and r.total_cost >= 0.0
    assert np.isfinite(r.intra_bytes) and np.isfinite(r.cross_bytes)


def _auc(rep: np.ndarray, mal: np.ndarray) -> float:
    """P(honest reputation > malicious reputation), ties at 0.5."""
    h, m = rep[~mal][:, None], rep[mal][None, :]
    return float((h > m).mean() + 0.5 * (h == m).mean())


@pytest.mark.slow
@pytest.mark.parametrize("name", list_scenarios("static"))
def test_reputation_ranks_honest_above_malicious(name):
    """Under each static paper attack, cost_trustfl's final EMA
    reputation separates honest from malicious clients (AUC > 0.5)."""
    fl = FLConfig(n_clouds=3, clients_per_cloud=6, clients_per_round=12,
                  local_epochs=1, local_batch=16, ref_samples=32)
    data = make_data(get_scenario(name).apply(fl), "cifar10", seed=0,
                     n_samples=2000, samples_per_client=48)
    r = run_simulation(fl, method="cost_trustfl", scenario=name, rounds=6,
                       eval_every=6, data=data, seed=0)
    assert r.malicious.any() and not r.malicious.all()
    assert _auc(r.reputation, r.malicious) > 0.5

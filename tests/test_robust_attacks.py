"""Baseline robust aggregators + attack transforms."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (apply_update_attack, coordinate_median, fedavg,
                        flip_labels, fltrust, gaussian_attack, krum,
                        scaling_attack, sign_flip_attack, trimmed_mean)


def _updates(n=10, d=32, outliers=3, scale=100.0, seed=0):
    rng = np.random.default_rng(seed)
    honest_dir = rng.normal(size=d)
    u = honest_dir + 0.1 * rng.normal(size=(n, d))
    u[:outliers] = scale * rng.normal(size=(outliers, d))
    return jnp.asarray(u, jnp.float32), honest_dir


def test_fedavg_is_mean():
    u = jnp.arange(12.0).reshape(3, 4)
    assert np.allclose(np.array(fedavg(u)), np.arange(12).reshape(3, 4)
                       .mean(0))


def test_fedavg_weighted():
    u = jnp.array([[0.0, 0.0], [1.0, 1.0]])
    out = fedavg(u, weights=jnp.array([1.0, 3.0]))
    assert np.allclose(np.array(out), [0.75, 0.75])


def test_krum_rejects_outliers():
    u, honest = _updates()
    out = np.array(krum(u, n_malicious=3))
    cos = out @ honest / (np.linalg.norm(out) * np.linalg.norm(honest))
    assert cos > 0.9


def test_trimmed_mean_bounds_outliers():
    u, honest = _updates()
    out = np.array(trimmed_mean(u, trim_frac=0.3))
    assert np.linalg.norm(out) < 5 * np.linalg.norm(honest)


def test_median_robust_to_half_minus_one():
    u, honest = _updates(n=11, outliers=5, scale=1e6)
    out = np.array(coordinate_median(u))
    assert np.linalg.norm(out) < 10 * np.linalg.norm(honest)


def test_fltrust_zeroes_antialigned():
    ref = jnp.ones(16)
    u = jnp.stack([jnp.ones(16), -jnp.ones(16), 2 * jnp.ones(16)])
    out = np.array(fltrust(u, ref))
    # normalized to ref norm, anti-aligned excluded
    assert np.allclose(out, np.ones(16), atol=1e-5)


# --- attacks -----------------------------------------------------------------

def test_label_flip_changes_only_masked():
    key = jax.random.PRNGKey(0)
    y = jnp.arange(10) % 5
    mask = jnp.array([True] * 5 + [False] * 5)
    y2 = flip_labels(y, 5, mask, key)
    assert (np.array(y2[5:]) == np.array(y[5:])).all()
    assert (np.array(y2[:5]) != np.array(y[:5])).all()   # offset in [1, C)


def test_sign_flip_negates_malicious_rows():
    u = jnp.ones((4, 8))
    mal = jnp.array([True, False, True, False])
    out = np.array(sign_flip_attack(u, mal))
    assert (out[0] == -1).all() and (out[1] == 1).all()


def test_sign_flip_honors_attack_scale():
    """FLConfig.attack_scale must reach the transform — the dispatcher
    used to hardcode scale=1.0 for sign_flip."""
    u = jnp.ones((2, 4))
    mal = jnp.array([True, False])
    out = np.array(sign_flip_attack(u, mal, scale=3.0))
    assert (out[0] == -3).all() and (out[1] == 1).all()
    key = jax.random.PRNGKey(0)
    via_dispatch = np.array(apply_update_attack("sign_flip", u, mal, key,
                                                scale=2.5))
    assert (via_dispatch[0] == -2.5).all() and (via_dispatch[1] == 1).all()


def test_scaling_attack_amplifies():
    u = jnp.ones((2, 4))
    out = np.array(scaling_attack(u, jnp.array([True, False]), scale=10.0))
    assert (out[0] == 10).all() and (out[1] == 1).all()


def test_gaussian_attack_adds_noise_only_to_malicious():
    key = jax.random.PRNGKey(1)
    u = jnp.zeros((3, 100))
    mal = jnp.array([True, False, False])
    out = np.array(gaussian_attack(u, mal, key, sigma=1.0))
    assert np.abs(out[0]).std() > 0.5
    assert (out[1:] == 0).all()


def test_apply_update_attack_dispatch():
    key = jax.random.PRNGKey(0)
    u = jnp.ones((2, 4))
    mal = jnp.array([True, False])
    for name in ("none", "label_flip"):
        assert (np.array(apply_update_attack(name, u, mal, key)) == 1).all()
    assert (np.array(apply_update_attack("sign_flip", u, mal, key,
                                         scale=1.0))[0] == -1).all()
    with pytest.raises(ValueError):
        apply_update_attack("bogus", u, mal, key)

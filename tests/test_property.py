"""Property-based tests (hypothesis) for the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_fallback import given, settings, st

from repro.core import (CloudTopology, CostModel, ReputationState,
                        cost_trustfl_aggregate, ema_update, fltrust,
                        gradient_contribution, normalize_scores,
                        select_clients, trusted_aggregate)

settings.register_profile("prop", max_examples=25, deadline=None)
settings.load_profile("prop")


@given(n=st.integers(2, 20), seed=st.integers(0, 10))
def test_reputation_simplex_invariant(n, seed):
    """Normalized scores always lie on the simplex; EMA preserves it."""
    rng = np.random.default_rng(seed)
    phi = jnp.asarray(np.abs(rng.normal(size=n)).astype(np.float32))
    r = normalize_scores(phi)
    assert float(r.sum()) == np.float32(1.0) or abs(float(r.sum()) - 1) < 1e-5
    assert (np.array(r) >= 0).all()
    st_ = ReputationState.init(n)
    st2 = ema_update(st_, r, gamma=0.7)
    assert abs(float(st2.ema.sum()) - 1) < 1e-5


@given(n=st.integers(1, 30), m=st.integers(1, 30), seed=st.integers(0, 5),
       lam=st.floats(0.0, 1.0))
def test_selection_cardinality_and_monotonicity(n, m, seed, lam):
    rng = np.random.default_rng(seed)
    rep = rng.random(n)
    costs = rng.choice([0.01, 0.09], n)
    sel = select_clients(rep, costs, m, cost_lambda=lam)
    assert sel.sum() == min(m, n)
    # monotonicity: every selected client has ratio >= every unselected
    ratio = rep / costs ** lam
    if sel.sum() < n:
        assert ratio[sel].min() >= ratio[~sel].max() - 1e-12


@given(k=st.integers(1, 5), npc=st.integers(1, 10), d=st.integers(1, 1000),
       seed=st.integers(0, 5))
def test_cost_hierarchical_never_exceeds_flat_or_bound(k, npc, d, seed):
    rng = np.random.default_rng(seed)
    topo = CloudTopology.even(k, npc)
    cm = CostModel()
    sel = rng.random(k * npc) < 0.7
    sel[0] = True
    hier = cm.round_cost(topo, sel, d, hierarchical=True)
    bound = cm.full_participation_cost(topo, d)
    assert hier <= bound + 1e-12
    assert hier >= 0


@given(n=st.integers(2, 12), d=st.integers(2, 64), seed=st.integers(0, 8))
def test_trusted_aggregate_in_convex_hull(n, d, seed):
    """Eq. 13 output is a convex combination: bounded by row extremes."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    ts = jnp.asarray(np.abs(rng.normal(size=n)).astype(np.float32)) + 0.01
    out = np.array(trusted_aggregate(g, ts))
    assert (out <= np.array(g).max(axis=0) + 1e-4).all()
    assert (out >= np.array(g).min(axis=0) - 1e-4).all()


@given(seed=st.integers(0, 10), scale=st.floats(2.0, 1000.0))
def test_fltrust_norm_bounded_by_reference(seed, scale):
    """Eq. 12 invariant: no attacker scaling can push the aggregate norm
    beyond the reference norm."""
    rng = np.random.default_rng(seed)
    ref = rng.normal(size=32).astype(np.float32)
    g = np.stack([ref + 0.1 * rng.normal(size=32) for _ in range(6)])
    g[0] *= scale                     # scaling attack
    out = np.array(fltrust(jnp.asarray(g), jnp.asarray(ref)))
    assert np.linalg.norm(out) <= np.linalg.norm(ref) * 1.05


@given(seed=st.integers(0, 10))
def test_aggregation_permutation_equivariance(seed):
    """Permuting clients permutes reputations and leaves the update
    unchanged (cloud structure held fixed)."""
    rng = np.random.default_rng(seed)
    n, d, k = 6, 24, 2
    u = rng.normal(size=(n, d)).astype(np.float32)
    refs = rng.normal(size=(k, d)).astype(np.float32)
    cloud = np.repeat(np.arange(k), n // k)
    perm = rng.permutation(n // k)    # permute within cloud 0
    full_perm = np.concatenate([perm, np.arange(n // k, n)])

    def agg(mat):
        res = cost_trustfl_aggregate(
            jnp.asarray(mat), jnp.asarray(mat[:, :8]), jnp.asarray(refs),
            jnp.asarray(refs[:, :8]), jnp.asarray(cloud),
            jnp.ones(n, bool), ReputationState.init(n))
        return np.array(res.update), np.array(res.trust)

    up1, ts1 = agg(u)
    up2, ts2 = agg(u[full_perm])
    np.testing.assert_allclose(up1, up2, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(ts1[full_perm], ts2, rtol=2e-4, atol=2e-5)


@given(n=st.integers(2, 10), d=st.integers(2, 32), c=st.floats(0.1, 10.0),
       seed=st.integers(0, 5))
def test_gradient_contribution_scale_equivariance(n, d, c, seed):
    """φ(c·G) = c·φ(G): Eq. 7 is 1-homogeneous (cos invariant, ‖·‖ linear)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    p1 = np.array(gradient_contribution(g)) * c
    p2 = np.array(gradient_contribution(g * c))
    np.testing.assert_allclose(p1, p2, rtol=2e-3, atol=1e-5)

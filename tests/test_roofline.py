"""Roofline analyzer: HLO collective parsing + pod classification +
term arithmetic on synthetic HLO text."""
import numpy as np
import pytest

from repro.roofline.analyze import (CollectiveOp, _shape_bytes,
                                    parse_collectives)

HLO = """
HloModule test
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p0), replica_groups={{0,1},{2,3}}, to_apply=%add
  %ag = bf16[64,512]{1,0} all-gather(%p0), replica_groups={{0,2},{1,3}}, dimensions={0}
  %rs = f32[32]{0} reduce-scatter(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = f32[16,16]{1,0} collective-permute(%p0), source_target_pairs={{0,1},{1,0}}
  %tuple = (f32[8]{0}, f32[8]{0}) all-to-all(%p0, %p0), replica_groups={{0,1}}
  %done = f32[4]{0} all-reduce-done(%ar)
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _shape_bytes("bf16[64,512]") == 64 * 512 * 2
    assert _shape_bytes("(f32[8]{0}, f32[8]{0})") == 64
    assert _shape_bytes("f32[]") == 4


def test_parse_collectives_kinds_and_bytes():
    ops = parse_collectives(HLO)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-to-all",
                     "collective-permute", "reduce-scatter"]
    ar = [o for o in ops if o.kind == "all-reduce"][0]
    assert ar.bytes == 128 * 256 * 4


def test_cross_pod_classification():
    # pods: devices 0,1 -> pod 0; devices 2,3 -> pod 1
    pod_of = np.array([0, 0, 1, 1])
    ops = parse_collectives(HLO, pod_of)
    by_kind = {o.kind: o for o in ops}
    assert not by_kind["all-reduce"].cross_pod        # {0,1},{2,3} intra
    assert by_kind["all-gather"].cross_pod            # {0,2} spans pods
    assert by_kind["reduce-scatter"].cross_pod        # {0,1,2,3}
    assert not by_kind["collective-permute"].cross_pod  # 0<->1 same pod


def test_iota_replica_groups():
    hlo = ("%ar = f32[64]{0} all-reduce(%x), "
           "replica_groups=[2,2]<=[4], to_apply=%a\n")
    pod_of = np.array([0, 0, 1, 1])
    ops = parse_collectives(hlo, pod_of)
    assert len(ops) == 1 and not ops[0].cross_pod     # groups {0,1},{2,3}
    pod_of2 = np.array([0, 1, 0, 1])
    assert parse_collectives(hlo, pod_of2)[0].cross_pod

"""Attention unit tests: masking disciplines, flash-decode equivalence,
q-chunk invariance, softcap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

import repro.models.attention as A
from repro.configs import get_arch, reduced


@pytest.fixture(scope="module")
def cfg():
    return replace(reduced(get_arch("gemma2-2b"), d_model=64),
                   n_heads=4, n_kv_heads=2, head_dim=16, window=8,
                   chunk=16, attn_softcap=0.0, rope_theta=10000.0)


def _setup(cfg, t=24, b=2, seed=0):
    key = jax.random.PRNGKey(seed)
    params = A.init_attn(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (b, t, cfg.d_model)) * 0.3
    return params, x


def test_q_chunk_invariance(cfg):
    """Output must not depend on the scan chunking."""
    params, x = _setup(cfg)
    y1 = A.attn_forward(params, x, cfg=cfg, layer_type="A", q_chunk=4)
    y2 = A.attn_forward(params, x, cfg=cfg, layer_type="A", q_chunk=24)
    np.testing.assert_allclose(np.array(y1), np.array(y2), atol=1e-5)


def test_q_chunk_padding_path(cfg):
    """t not divisible by q_chunk exercises the pad branch."""
    params, x = _setup(cfg, t=23)
    y1 = A.attn_forward(params, x, cfg=cfg, layer_type="A", q_chunk=8)
    y2 = A.attn_forward(params, x, cfg=cfg, layer_type="A", q_chunk=23)
    np.testing.assert_allclose(np.array(y1), np.array(y2), atol=1e-5)


def test_causality(cfg):
    params, x = _setup(cfg)
    y1 = A.attn_forward(params, x, cfg=cfg, layer_type="A")
    x2 = x.at[:, -1].add(10.0)
    y2 = A.attn_forward(params, x2, cfg=cfg, layer_type="A")
    np.testing.assert_allclose(np.array(y1[:, :-1]), np.array(y2[:, :-1]),
                               atol=1e-5)


def test_window_mask_blocks_far_tokens(cfg):
    params, x = _setup(cfg)
    y1 = A.attn_forward(params, x, cfg=cfg, layer_type="L")
    x2 = x.at[:, 0].add(10.0)          # outside window 8 for pos >= 8
    y2 = A.attn_forward(params, x2, cfg=cfg, layer_type="L")
    np.testing.assert_allclose(np.array(y1[:, 10:]), np.array(y2[:, 10:]),
                               atol=1e-5)


def test_chunk_mask_blocks_cross_chunk(cfg):
    params, x = _setup(cfg, t=40)
    y1 = A.attn_forward(params, x, cfg=cfg, layer_type="C")
    x2 = x.at[:, 3].add(10.0)          # chunk 0 (chunk size 16)
    y2 = A.attn_forward(params, x2, cfg=cfg, layer_type="C")
    # positions in chunk 1 (16..31) never see chunk 0
    np.testing.assert_allclose(np.array(y1[:, 16:]), np.array(y2[:, 16:]),
                               atol=1e-5)


def test_flash_decode_matches_plain(cfg, monkeypatch):
    key = jax.random.PRNGKey(0)
    b, s, kv, g, hd = 2, 50, 2, 2, 16
    q = jax.random.normal(key, (b, 1, kv, g, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, hd))
    valid = jnp.arange(s) < 37
    ref = A._sdpa(q, k, v, valid[None, None, None, None, :], 0.0)
    monkeypatch.setattr(A, "_DECODE_CHUNK", 16)
    out = A._decode_attn(q, k, v, valid, 0.0)
    np.testing.assert_allclose(np.array(out), np.array(ref), atol=2e-6)
    # with softcap
    ref2 = A._sdpa(q, k, v, valid[None, None, None, None, :], 30.0)
    out2 = A._decode_attn(q, k, v, valid, 30.0)
    np.testing.assert_allclose(np.array(out2), np.array(ref2), atol=2e-6)


def test_ring_cache_slots(cfg):
    """Sliding-window cache reuses slots mod window; decode at position
    >= window keeps exactly the last `window` keys valid."""
    c = A.init_attn_cache(cfg, "L", batch=1, max_len=100)
    assert c["k"].shape[1] == cfg.window
    params, x = _setup(cfg, t=1, b=1)
    cache = c
    for i in range(12):                 # > window=8
        _, cache = A.attn_decode(params, x, cache, jnp.asarray(i),
                                 cfg=cfg, layer_type="L")
    pos = np.array(cache["pos"])
    assert sorted(pos.tolist()) == list(range(4, 12))


def test_softcap_changes_scores(cfg):
    params, x = _setup(cfg)
    cfg_cap = replace(cfg, attn_softcap=5.0)
    y1 = A.attn_forward(params, x, cfg=cfg, layer_type="A")
    y2 = A.attn_forward(params, x, cfg=cfg_cap, layer_type="A")
    assert float(jnp.abs(y1 - y2).max()) > 1e-6

"""repro.compress: codec invariants (EF telescoping, QSGD unbiasedness,
exact wire bytes), per-link policy resolution, CostModel payload
accounting, and the compressed end-to-end simulation path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.compress import (LinkPolicy, QSGDCodec, TopKCodec,
                            build_link_policy, ef_step, make_codec)
from repro.configs.base import FLConfig
from repro.core import CloudTopology, CostModel

settings.register_profile("compress", max_examples=15, deadline=None)
settings.load_profile("compress")

_FL = dict(n_clouds=3, clients_per_cloud=4, clients_per_round=6,
           local_epochs=1, local_batch=16, ref_samples=32)


# -- codec invariants ---------------------------------------------------------

@given(n=st.sampled_from([1, 4]), d=st.sampled_from([32, 400]),
       ratio=st.sampled_from([0.02, 0.1, 0.5]), seed=st.integers(0, 5))
def test_topk_ef_residuals_telescope(n, d, ratio, seed):
    """Error feedback loses nothing: Σ transmitted = Σ input - residual."""
    codec = make_codec("topk", ratio=ratio)
    key = jax.random.PRNGKey(seed)
    res = jnp.zeros((n, d))
    tot_x = jnp.zeros((n, d))
    tot_hat = jnp.zeros((n, d))
    for t in range(8):
        xt = jax.random.normal(jax.random.fold_in(key, t), (n, d))
        x_hat, res = ef_step(codec, xt, res, jax.random.fold_in(key, 50 + t))
        tot_x = tot_x + xt
        tot_hat = tot_hat + x_hat
    np.testing.assert_allclose(np.array(tot_hat + res), np.array(tot_x),
                               rtol=1e-4, atol=1e-4)


def test_qsgd_decompression_unbiased():
    """E[decode(encode(x))] = x: the mean over independent noise draws
    converges to the input at the Monte-Carlo rate."""
    codec = make_codec("qsgd", levels=15)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 200))
    draws = 200
    acc = jnp.zeros_like(x)
    for i in range(draws):
        acc = acc + codec.roundtrip(x, jax.random.PRNGKey(100 + i))
    err = np.abs(np.array(acc / draws - x)).max()
    # per-coordinate quantization step is scale/L; MC error ~ step/sqrt(M)
    step = float(jnp.max(jnp.abs(x))) / codec.levels
    assert err < 5 * step / np.sqrt(draws)


def test_topk_roundtrip_matches_structured_wire_form():
    """The fused kernel path == decode(encode(.)) (dense scatter)."""
    codec = TopKCodec(ratio=0.1)
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (5, 333))
    rt = codec.roundtrip(x, key)
    dec = codec.decode(codec.encode(x, key))
    np.testing.assert_allclose(np.array(rt), np.array(dec),
                               rtol=1e-3, atol=1e-3)
    assert int((np.array(rt) != 0).sum(axis=1).max()) == codec.k_for(333)


def test_payload_bytes_exact():
    d = 1000
    assert make_codec("none").payload_bytes(d) == 4 * d
    tk = make_codec("topk", ratio=0.1)
    assert tk.payload_bytes(d) == 4 + 100 * (2 + 4)      # hdr + k*(fp16+i32)
    q = make_codec("qsgd", levels=15)                     # 31 states -> 5 bits
    assert q.payload_bytes(d) == 4 + (d * 5 + 7) // 8
    with pytest.raises(ValueError):
        make_codec("zfp")


def test_link_policy_resolution():
    lp = build_link_policy("topk", ratio=0.1, link_policy="cross_only")
    assert lp.intra.is_identity and not lp.cross.is_identity
    lp = build_link_policy("qsgd", link_policy="all")
    assert not lp.intra.is_identity and not lp.cross.is_identity
    assert not build_link_policy("none", link_policy="all").any_active
    assert not build_link_policy("topk", link_policy="none").any_active
    with pytest.raises(ValueError):
        build_link_policy("topk", link_policy="edge_only")


# -- CostModel payload accounting ---------------------------------------------

def test_round_bytes_with_payloads_matches_hand_count():
    topo = CloudTopology.even(3, 4)                       # aggregator cloud 0
    cm = CostModel()
    sel = np.zeros(12, bool)
    sel[[0, 1, 4, 8]] = True                              # clouds 0,0,1,2
    client = np.full(12, 100.0)
    edge = np.array([10.0, 20.0, 30.0])
    intra, cross = cm.round_bytes(topo, sel, 1, client_payload=client,
                                  edge_payload=edge)
    assert intra == 4 * 100 + 10                          # uplinks + agg edge
    assert cross == 20 + 30
    # flat path: same-cloud clients are intra, the rest cross
    intra_f, cross_f = cm.round_bytes(topo, sel, 1, hierarchical=False,
                                      client_payload=client)
    assert intra_f == 2 * 100 and cross_f == 2 * 100


def test_bytes_per_round_defaults_to_fp32():
    topo = CloudTopology.even(2, 3)
    cm = CostModel()
    sel = np.ones(6, bool)
    b = cm.bytes_per_round(topo, sel, 1000)
    assert b["intra"] == 6 * 4000 + 4000                  # + agg-cloud edge
    assert b["cross"] == 4000
    assert b["total"] == b["intra"] + b["cross"]


# -- end-to-end compressed simulation -----------------------------------------

@pytest.fixture(scope="module")
def sim_data():
    from repro.federated import make_data
    fl = FLConfig(**_FL)
    return make_data(fl, "cifar10", seed=0, n_samples=2000,
                     samples_per_client=48)


@pytest.mark.slow
def test_compressed_simulation_converges_under_label_flip(sim_data):
    """topk/cross_only run stays trainable under attack and cuts
    cross-cloud bytes >= 5x vs the uncompressed run."""
    from repro.federated import run_simulation
    fl = FLConfig(attack="label_flip", malicious_frac=0.3, **_FL)
    base = run_simulation(fl, method="cost_trustfl", rounds=3, eval_every=3,
                          data=sim_data, seed=0)
    flc = FLConfig(attack="label_flip", malicious_frac=0.3,
                   compressor="topk", compress_ratio=0.1,
                   link_policy="cross_only", **_FL)
    comp = run_simulation(flc, method="cost_trustfl", rounds=3, eval_every=3,
                          data=sim_data, seed=0)
    assert 0.0 <= comp.final_accuracy <= 1.0
    assert np.isfinite(comp.total_cost)
    assert base.cross_bytes / comp.cross_bytes >= 5.0
    assert comp.intra_bytes == base.intra_bytes       # intra left untouched
    assert comp.total_cost < base.total_cost


@pytest.mark.slow
def test_flat_baseline_compresses_cross_clients_only(sim_data):
    """fedavg (flat path): cross_only compresses remote clients' uplinks,
    aggregator-cloud clients stay fp32."""
    from repro.federated import FLServer, make_topology
    fl = FLConfig(compressor="topk", compress_ratio=0.1,
                  link_policy="cross_only", **_FL)
    server = FLServer(fl, make_topology(fl), sim_data, method="fedavg",
                      seed=0)
    m = server.run_round(0)
    d = server.d_params
    sel = m.selected
    same = server.topo.cloud_of == server.topo.aggregator_cloud
    tk = server.link_policy.cross
    assert m.extra["intra_bytes"] == 4 * d * (sel & same).sum()
    assert m.extra["cross_bytes"] == tk.payload_bytes(d) * (sel & ~same).sum()


def test_rounds_zero_returns_explicit_nones(sim_data):
    from repro.federated import run_simulation
    fl = FLConfig(**_FL)
    r = run_simulation(fl, method="fedavg", rounds=0, data=sim_data, seed=0)
    assert r.final_accuracy is None
    assert r.accuracy == [] and r.rounds == []
    assert r.total_cost == 0.0

"""Distributed FL train-step semantics on a small multi-device mesh
(run in a subprocess with 8 host devices so the main test process keeps
1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["REPRO_NO_DONATE"] = "1"   # params are reused across strategies
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro.models import build_model
    from repro.configs.base import FLConfig
    from repro.train import make_fl_train_step
    from repro.optim import sgd

    out = {}
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    m = build_model("gemma2-2b", smoke=True)
    fl = FLConfig(n_clouds=2, clients_per_round=3)
    opt = sgd(0.05)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    opt_state = opt[0](params)
    batch = m.dummy_batch(key, batch=8, seq=32)
    ref = m.dummy_batch(jax.random.PRNGKey(9), batch=4, seq=32)
    ref = jax.tree.map(lambda x: x.reshape((2, 2) + x.shape[1:]), ref)

    for strat in ("two_phase", "fused"):
        step, topo = make_fl_train_step(m, mesh, fl, opt, strategy=strat)
        rep = jnp.full((topo.n_clients,), 1.0 / topo.n_clients)
        args = [params, opt_state, rep, batch, ref]
        if strat == "fused":
            args.append(jax.random.PRNGKey(1))
        p2, o2, rep2, met = step(*args)
        delta = jax.tree.reduce(jnp.add, jax.tree.map(
            lambda a, b: jnp.sum(jnp.abs(a.astype(jnp.float32)
                                         - b.astype(jnp.float32))),
            params, p2))
        out[strat] = {
            "loss": float(met["loss"]),
            "delta": float(delta),
            "rep_sum": float(jnp.sum(rep2)),
            "selected": int(np.array(met["selected"]).sum()),
            "phi_nonneg": bool((np.array(met["phi"]) >= -1e-6).all()),
            "finite": bool(all(np.isfinite(np.asarray(x, np.float32)).all()
                               for x in jax.tree.leaves(p2))),
        }
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def step_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


@pytest.mark.parametrize("strategy", ["two_phase", "fused"])
def test_fl_step_trains_and_is_sane(step_results, strategy):
    r = step_results[strategy]
    assert r["finite"]
    assert r["delta"] > 0, "params did not move"
    assert r["loss"] > 0
    assert r["selected"] == 3            # m = clients_per_round
    assert r["phi_nonneg"]
    assert abs(r["rep_sum"] - 1.0) < 0.5  # EMA keeps total mass ~1


def test_strategies_agree_on_loss(step_results):
    a = step_results["two_phase"]["loss"]
    b = step_results["fused"]["loss"]
    assert abs(a - b) / max(a, 1e-9) < 0.05

"""Unit tests for trust/reputation/selection/cost/aggregation (Eq. 1–13)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CloudTopology, CostModel, ReputationState,
                        cloud_trust, cost_trustfl_aggregate, ema_update,
                        normalize_scores, normalize_updates, select_clients,
                        select_clients_jax, trust_scores, trusted_aggregate)


# --- Eq. 8–9 -----------------------------------------------------------------

def test_normalize_scores_sums_to_one():
    phi = jnp.array([1.0, 2.0, 3.0, 0.0])
    r = normalize_scores(phi)
    assert np.isclose(float(r.sum()), 1.0)
    assert np.isclose(float(r[2]), 0.5)


def test_normalize_scores_all_zero_is_uniform():
    r = normalize_scores(jnp.zeros(4))
    assert np.allclose(np.array(r), 0.25)


def test_ema_update_blends_and_respects_participation():
    st = ReputationState.init(4)
    r_new = jnp.array([0.4, 0.3, 0.2, 0.1])
    part = jnp.array([True, True, False, False])
    st2 = ema_update(st, r_new, gamma=0.5, participated=part)
    assert np.isclose(float(st2.ema[0]), 0.5 * 0.25 + 0.5 * 0.4)
    assert np.isclose(float(st2.ema[2]), 0.25)          # untouched


# --- Eq. 11–13 ---------------------------------------------------------------

def test_trust_scores_zero_for_antialigned():
    ref = jnp.ones((1, 8))
    g = jnp.stack([jnp.ones(8), -jnp.ones(8)])
    ts = trust_scores(g, ref[0], jnp.array([0.5, 0.5]))
    assert float(ts[0]) > 0 and float(ts[1]) == 0.0


def test_normalize_updates_matches_ref_norm():
    g = jnp.array([[3.0, 4.0], [6.0, 8.0]])
    ref = jnp.array([1.0, 0.0])
    gt = normalize_updates(g, ref)
    assert np.allclose(np.linalg.norm(np.array(gt), axis=1), 1.0)


def test_trusted_aggregate_is_convex_combination():
    g = jnp.array([[1.0, 0.0], [0.0, 1.0]])
    ts = jnp.array([3.0, 1.0])
    out = np.array(trusted_aggregate(g, ts))
    assert np.allclose(out, [0.75, 0.25])


def test_cloud_trust_normalizes():
    g = jnp.array([[1.0, 0.0], [1.0, 0.1], [-1.0, 0.0]])
    ref = jnp.array([1.0, 0.0])
    beta = np.array(cloud_trust(g, ref))
    assert np.isclose(beta.sum(), 1.0) and beta[2] == 0.0


# --- Eq. 10 (selection) ------------------------------------------------------

def test_selection_prefers_cheap_clients_at_equal_reputation():
    rep = np.full(6, 1.0)
    costs = np.array([0.01, 0.01, 0.09, 0.09, 0.09, 0.09])
    sel = select_clients(rep, costs, m=2)
    assert sel[:2].all() and not sel[2:].any()


def test_selection_prefers_reputation_at_equal_cost():
    rep = np.array([0.1, 0.9, 0.5, 0.7])
    sel = select_clients(rep, np.full(4, 0.09), m=2)
    assert sel[1] and sel[3] and not sel[0]


def test_selection_jax_matches_numpy():
    rng = np.random.default_rng(0)
    rep = rng.random(16).astype(np.float32)
    costs = rng.choice([0.01, 0.09], 16).astype(np.float32)
    a = select_clients(rep, costs, m=5)
    b = np.array(select_clients_jax(jnp.asarray(rep), jnp.asarray(costs), 5))
    assert (a == b).all()


def test_selection_per_cloud_quota():
    rep = np.array([1.0, 1.0, 1.0, 0.0, 0.0, 0.0])
    cloud = np.array([0, 0, 0, 1, 1, 1])
    sel = select_clients(rep, np.full(6, 0.01), m=4, per_cloud_min=1,
                         cloud_of=cloud)
    assert sel[3:].sum() >= 1                     # cloud 1 kept alive


# --- Eq. 1–3 (cost) ----------------------------------------------------------

def test_flat_cost_matches_eq1():
    topo = CloudTopology.even(3, 2)
    cm = CostModel(c_intra=0.01, c_cross=0.09, bytes_per_param=4)
    sel = np.array([True] * 6)
    d = 1024 ** 3 // 4                            # exactly 1 GB of params
    flat = cm.round_cost(topo, sel, d, hierarchical=False)
    # 2 clients intra (cloud 0) + 4 cross
    assert np.isclose(flat, 2 * 0.01 + 4 * 0.09)


def test_hierarchical_cheaper_than_flat():
    topo = CloudTopology.even(3, 30)
    cm = CostModel()
    sel = np.ones(90, bool)
    d = 10_000_000
    assert cm.round_cost(topo, sel, d, True) < cm.round_cost(topo, sel, d,
                                                             False)


def test_full_participation_upper_bound_eq3():
    topo = CloudTopology.even(3, 30)
    cm = CostModel()
    d = 10_000_000
    assert cm.round_cost(topo, np.ones(90, bool), d, True) <= \
        cm.full_participation_cost(topo, d) + 1e-9


# --- full aggregation pipeline ----------------------------------------------

def _setup_agg(n=12, d=64, k=3, seed=0):
    rng = np.random.default_rng(seed)
    ref_dir = rng.normal(size=d)
    honest = 0.9 * ref_dir + 0.3 * rng.normal(size=(n, d))
    refs = 0.95 * ref_dir + 0.1 * rng.normal(size=(k, d))
    return (jnp.asarray(honest, jnp.float32), jnp.asarray(refs, jnp.float32),
            jnp.asarray(np.repeat(np.arange(k), n // k)))


def test_aggregate_downweights_scaled_attackers():
    u, refs, cloud = _setup_agg()
    u_attacked = u.at[0].multiply(100.0)          # scaling attack
    res = cost_trustfl_aggregate(
        u_attacked, u_attacked[:, :16], refs, refs[:, :16], cloud,
        jnp.ones(12, bool), ReputationState.init(12))
    # Eq. 12 rescales: the aggregate norm stays at reference scale
    assert float(jnp.linalg.norm(res.update)) < 10 * float(
        jnp.linalg.norm(refs[0]))


def test_aggregate_zeroes_sign_flippers():
    u, refs, cloud = _setup_agg()
    u_attacked = u.at[:4].multiply(-1.0)
    res = cost_trustfl_aggregate(
        u_attacked, u_attacked[:, :16], refs, refs[:, :16], cloud,
        jnp.ones(12, bool), ReputationState.init(12))
    trust = np.array(res.trust)
    assert trust[:4].max() <= trust[4:].min() + 1e-9
    # the update still points along the honest direction
    cos = float(u[5] @ res.update /
                (jnp.linalg.norm(u[5]) * jnp.linalg.norm(res.update)))
    assert cos > 0.5


def test_norm_inflation_cannot_farm_reputation():
    """φ damping: a client submitting 10× the honest norm must not end up
    with the top contribution score (regression for the scaling/gaussian
    scenarios, where raw Eq. 7 rewarded norm inflation)."""
    u, refs, cloud = _setup_agg()
    u_attacked = u.at[0].multiply(10.0)
    res = cost_trustfl_aggregate(
        u_attacked, u_attacked[:, :16], refs, refs[:, :16], cloud,
        jnp.ones(12, bool), ReputationState.init(12))
    phi = np.array(res.phi)
    assert phi[0] <= np.median(phi[1:]) + 1e-6


def test_aggregate_beta_sums_to_one():
    u, refs, cloud = _setup_agg()
    res = cost_trustfl_aggregate(u, u[:, :16], refs, refs[:, :16], cloud,
                                 jnp.ones(12, bool),
                                 ReputationState.init(12))
    assert np.isclose(float(res.beta.sum()), 1.0, atol=1e-5)


def test_aggregate_ignores_unselected():
    u, refs, cloud = _setup_agg()
    poisoned = u.at[0].set(1e6)
    sel = jnp.ones(12, bool).at[0].set(False)
    res = cost_trustfl_aggregate(poisoned, poisoned[:, :16], refs,
                                 refs[:, :16], cloud, sel,
                                 ReputationState.init(12))
    assert float(res.trust[0]) == 0.0
    assert np.isfinite(np.array(res.update)).all()

"""Unit tests for the CI bench-regression gate (benchmarks/compare.py):
the gate's semantics are load-bearing for CI, so they are pinned here —
only throughput keys are gated, missing metrics fail, new metrics and
ratio/config keys pass through."""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.compare import compare  # noqa: E402


BASE = {
    "host_rounds_per_s": 10.0,
    "scan_rounds_per_s": 100.0,
    "speedup_scan_vs_host": 10.0,          # ratio: not gated
    "fleet_config": {"n_clouds": 3},       # config echo: not gated
}


def test_gate_passes_within_threshold():
    cur = dict(BASE, host_rounds_per_s=8.0, scan_rounds_per_s=76.0)
    assert compare(cur, BASE, threshold=0.25) == []


def test_gate_fails_on_big_drop():
    cur = dict(BASE, scan_rounds_per_s=70.0)
    failures = compare(cur, BASE, threshold=0.25)
    assert len(failures) == 1 and "scan_rounds_per_s" in failures[0]


def test_gate_fails_on_missing_metric():
    cur = {"host_rounds_per_s": 10.0}
    failures = compare(cur, BASE, threshold=0.25)
    assert any("missing" in f and "scan_rounds_per_s" in f
               for f in failures)


def test_ratio_and_config_keys_are_not_gated():
    cur = dict(BASE, speedup_scan_vs_host=1.0)   # ratio collapsed 10x
    assert compare(cur, BASE, threshold=0.25) == []


def test_new_metrics_pass_until_baseline_refresh():
    cur = dict(BASE, sharded_rounds_per_s=1.0)
    assert compare(cur, BASE, threshold=0.25) == []


def test_threshold_is_respected():
    cur = dict(BASE, host_rounds_per_s=7.4)      # -26%
    assert compare(cur, BASE, threshold=0.25) != []
    assert compare(cur, BASE, threshold=0.30) == []

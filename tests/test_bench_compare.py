"""Unit tests for the CI bench-regression gate (benchmarks/compare.py):
the gate's semantics are load-bearing for CI, so they are pinned here —
only throughput keys are gated, missing metrics fail, new metrics and
ratio/config keys pass through."""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.compare import compare, main  # noqa: E402


BASE = {
    "host_rounds_per_s": 10.0,
    "scan_rounds_per_s": 100.0,
    "speedup_scan_vs_host": 10.0,          # ratio: not gated
    "fleet_config": {"n_clouds": 3},       # config echo: not gated
}


def test_gate_passes_within_threshold():
    cur = dict(BASE, host_rounds_per_s=8.0, scan_rounds_per_s=76.0)
    assert compare(cur, BASE, threshold=0.25) == []


def test_gate_fails_on_big_drop():
    cur = dict(BASE, scan_rounds_per_s=70.0)
    failures = compare(cur, BASE, threshold=0.25)
    assert len(failures) == 1 and "scan_rounds_per_s" in failures[0]


def test_gate_fails_on_missing_metric():
    cur = {"host_rounds_per_s": 10.0}
    failures = compare(cur, BASE, threshold=0.25)
    assert any("missing" in f and "scan_rounds_per_s" in f
               for f in failures)


def test_ratio_and_config_keys_are_not_gated():
    cur = dict(BASE, speedup_scan_vs_host=1.0)   # ratio collapsed 10x
    assert compare(cur, BASE, threshold=0.25) == []


def test_new_metrics_pass_until_baseline_refresh():
    cur = dict(BASE, sharded_rounds_per_s=1.0)
    assert compare(cur, BASE, threshold=0.25) == []


def test_threshold_is_respected():
    cur = dict(BASE, host_rounds_per_s=7.4)      # -26%
    assert compare(cur, BASE, threshold=0.25) != []
    assert compare(cur, BASE, threshold=0.30) == []


# -- missing / malformed baseline handling (the CLI layer) --------------------

def _write(tmp_path, name, text):
    p = tmp_path / name
    p.write_text(text)
    return str(p)


def test_missing_baseline_skips_with_warning(tmp_path, capsys):
    """A bench whose baseline is not committed yet must WARN and pass
    (exit 0), not hard-fail every CI run until the baseline lands."""
    cur = _write(tmp_path, "cur.json", '{"scan_rounds_per_s": 1.0}')
    missing = str(tmp_path / "nope.json")
    assert main(["--current", cur, "--baseline", missing]) == 0
    err = capsys.readouterr().err
    assert "WARNING" in err and "nope.json" in err


def test_malformed_baseline_fails_loudly(tmp_path):
    """A baseline that EXISTS but does not parse is corruption, not a
    coverage gap — it must never read as a pass."""
    cur = _write(tmp_path, "cur.json", '{"scan_rounds_per_s": 1.0}')
    bad = _write(tmp_path, "base.json", "{not json")
    with pytest.raises(Exception):
        main(["--current", cur, "--baseline", bad])


def test_missing_current_still_fails(tmp_path):
    """The skip is for absent BASELINES only: a missing current-run
    artifact means the bench itself did not run."""
    base = _write(tmp_path, "base.json", '{"scan_rounds_per_s": 1.0}')
    with pytest.raises(FileNotFoundError):
        main(["--current", str(tmp_path / "absent.json"),
              "--baseline", base])

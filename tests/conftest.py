import jax
import pytest

# Tests run single-device CPU (the dry-run alone uses 512 placeholder
# devices — never set xla_force_host_platform_device_count here).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)

"""Mesh-sharded round engine (``repro.federated.sharded``): support
gating + driver routing (fast), and the 1×1-mesh parity contract against
the single-device scan engine (slow).

Parity tolerance: selection/delivery masks and byte/cost accounting are
EXACT (the sharded engine evaluates the same replicated closures and the
same ``round_bytes`` reduction on the same masks); reputation and params
agree to ~1e-4 relative — psum partial sums associate differently than
the scan engine's flat matmuls, so bitwise equality is not promised.
"""
import numpy as np
import pytest

from repro.configs.base import FLConfig
from repro.core.fl_types import CloudTopology
from repro.federated import (FLServer, make_data, make_topology,
                             run_simulation, run_simulation_sharded)
from repro.federated import engine as engine_mod
from repro.federated import sharded as sharded_mod
from repro.scenarios import Scenario, get_scenario

_FL = dict(n_clouds=3, clients_per_cloud=4, clients_per_round=6,
           local_epochs=1, local_batch=8, ref_samples=16,
           attack="sign_flip", malicious_frac=0.3, attack_scale=1.0)

REP_TOL = dict(rtol=1e-4, atol=1e-6)
ACC_TOL = 0.01    # tiny param deltas may flip isolated test-set argmaxes


def _fl(**over) -> FLConfig:
    cfg = dict(_FL)
    cfg.update(over)
    return FLConfig(**cfg)


# ---------------------------------------------------------------------------
# support gating + routing (fast: no simulation runs)

def test_mesh_axes_factorization():
    """The cloud axis takes the largest common divisor, columns own
    whole clouds, and populations must tile the device count."""
    assert sharded_mod.mesh_axes(4, 1024, 8) == (4, 2)
    assert sharded_mod.mesh_axes(3, 12, 1) == (1, 1)
    assert sharded_mod.mesh_axes(3, 12, 3) == (3, 1)
    assert sharded_mod.mesh_axes(32, 1024, 8) == (8, 1)
    assert sharded_mod.mesh_axes(3, 12, 8) is None     # 12 % 8 != 0
    assert sharded_mod.mesh_axes(3, 12, 24) is None    # > 1 shard/client


@pytest.mark.parametrize("over,frag", [
    (dict(attack="gaussian"), "matrix-shaped"),
    (dict(attack="min_max"), "matrix-shaped"),
])
def test_shard_rejects_matrix_shaped_configs(over, frag):
    """Attacks whose randomness or statistics are tied to the selected
    matrix's layout must be refused loudly."""
    fl = _fl(**over)
    topo = make_topology(fl)
    reason = sharded_mod.shard_unsupported_reason(fl, topo, "cost_trustfl")
    assert reason is not None and frag in reason
    with pytest.raises(ValueError, match=frag):
        engine_mod.resolve_engine("shard", fl, topo, "cost_trustfl")


def test_shard_accepts_qsgd():
    """qsgd keys its rounding noise per SENDER (fold_in(client_id)), so
    the noise stream no longer depends on the matrix layout and the
    sharded engine runs it — the old refusal is gone."""
    fl = _fl(compressor="qsgd", compress_ratio=0.25, link_policy="all")
    topo = make_topology(fl)
    assert sharded_mod.shard_unsupported_reason(fl, topo,
                                                "cost_trustfl") is None


@pytest.mark.parametrize("method", ["krum", "trimmed_mean", "median"])
def test_shard_rejects_dropout_with_order_statistics(method):
    """Masked-delivery zero rows would count as extra clients for the
    order-statistic aggregators — same exclusion as the scan engine."""
    fl = _fl()
    topo = make_topology(fl)
    sc = get_scenario("dropout")
    reason = sharded_mod.shard_unsupported_reason(fl, topo, method, sc)
    assert reason is not None and "order-statistic" in reason
    with pytest.raises(ValueError, match="order-statistic"):
        engine_mod.resolve_engine("shard", fl, topo, method, sc)


def test_shard_rejects_host_hook_scenarios():
    sc = Scenario(name="hosty", level="environment",
                  deliver=lambda srv, t, rng, sel: sel)
    fl = _fl()
    reason = sharded_mod.shard_unsupported_reason(fl, make_topology(fl),
                                                  "cost_trustfl", sc)
    assert reason is not None and "host-only hooks" in reason


def test_shard_rejects_uneven_topology():
    fl = _fl()
    topo = CloudTopology(cloud_of=np.array([0] * 7 + [1] * 5), n_clouds=2,
                         aggregator_cloud=0)
    reason = sharded_mod.shard_unsupported_reason(fl, topo, "cost_trustfl")
    assert reason is not None and "contiguous" in reason


def test_shard_rejects_untileable_population():
    fl = _fl()   # N = 12
    topo = make_topology(fl)
    reason = sharded_mod.shard_unsupported_reason(fl, topo, "cost_trustfl",
                                                  n_devices=5)
    # the message must report the ACTUAL device count it was asked to
    # tile, not whatever len(jax.devices()) happens to be
    assert reason is not None and "tile" in reason and "5 devices" in reason


def test_auto_routes_uneven_topology_to_scan():
    """engine="auto" with a non-even client→cloud map silently falls
    back to the scan engine (a refusal is only for FORCED shard)."""
    fl = _fl()
    topo = CloudTopology(cloud_of=np.array([0] * 7 + [1] * 5), n_clouds=2,
                         aggregator_cloud=0)
    for n_dev in (1, 2, 4):
        assert engine_mod.resolve_engine("auto", fl, topo, "cost_trustfl",
                                         n_devices=n_dev) == "jit"
    with pytest.raises(ValueError, match="contiguous"):
        engine_mod.resolve_engine("shard", fl, topo, "cost_trustfl",
                                  n_devices=4)


def test_resolve_engine_routing():
    """auto: shard only with >1 device + dense participation + support;
    jit when the scan engine can run it; host for everything else."""
    fl = _fl()                       # N=12, m=6 -> dense (2*6 >= 12)
    topo = make_topology(fl)
    resolve = engine_mod.resolve_engine
    assert resolve("auto", fl, topo, "cost_trustfl", n_devices=1) == "jit"
    assert resolve("auto", fl, topo, "cost_trustfl", n_devices=4) == "shard"
    # sparse participation: masked all-client training would waste work
    sparse = _fl(clients_per_round=3)
    assert resolve("auto", sparse, topo, "fedavg", n_devices=4) == "jit"
    # forcing shard skips the density heuristic
    assert resolve("shard", sparse, topo, "fedavg", n_devices=4) == "shard"
    # shard-unsupported but jittable combination falls back to jit
    gauss = _fl(attack="gaussian")
    assert resolve("auto", gauss, topo, "cost_trustfl", n_devices=4) == "jit"
    # dropout x order statistics must land on the host loop
    sc = get_scenario("dropout")
    assert resolve("auto", fl, topo, "krum", sc, n_devices=4) == "host"
    assert resolve("auto", fl, topo, "krum", sc, n_devices=1) == "host"
    # ...while masked-delivery-safe aggregators stay on a device engine
    assert resolve("auto", sc.apply(fl), topo, "cost_trustfl", sc,
                   n_devices=1) == "jit"
    with pytest.raises(ValueError, match="not jittable"):
        resolve("jit", fl, topo, "krum", sc)
    with pytest.raises(ValueError, match="unknown engine"):
        resolve("tpu", fl, topo, "cost_trustfl")


def test_engine_auto_falls_back_to_host_on_server():
    """Routing regression at the FLServer level: dropout + krum must run
    the legacy host loop (no compiled engine attached)."""
    fl = get_scenario("dropout").apply(_fl())
    topo = make_topology(fl)
    data = make_data(fl, "cifar10", seed=0, n_samples=300,
                     samples_per_client=8)
    srv = FLServer(fl, topo, data, method="krum", seed=0,
                   scenario=get_scenario("dropout"))
    assert srv._eng is None
    with pytest.raises(ValueError, match="order-statistic"):
        FLServer(fl, topo, data, method="krum", seed=0,
                 scenario=get_scenario("dropout"), engine="shard")


# ---------------------------------------------------------------------------
# 1×1-mesh parity vs the scan engine (slow)

@pytest.fixture(scope="module")
def shared_data():
    return make_data(_fl(), "cifar10", seed=0, n_samples=600,
                     samples_per_client=16)


def _assert_parity(a, b):
    """a = scan-engine SimResult, b = sharded SimResult."""
    assert a.total_cost == b.total_cost
    assert a.intra_bytes == b.intra_bytes
    assert a.cross_bytes == b.cross_bytes
    assert np.array_equal(a.malicious, b.malicious)
    np.testing.assert_allclose(a.reputation, b.reputation, **REP_TOL)
    assert abs(a.final_accuracy - b.final_accuracy) <= ACC_TOL


def _pair(fl, method, data, scenario=None, rounds=3):
    a = run_simulation(fl, method=method, scenario=scenario, rounds=rounds,
                       eval_every=rounds, data=data, seed=0, engine="jit")
    b = run_simulation_sharded(fl, method=method, scenario=scenario,
                               rounds=rounds, data=data, seed=0,
                               n_devices=1)
    return a, b


@pytest.mark.slow
@pytest.mark.parametrize("method", ["cost_trustfl", "fedavg", "krum",
                                    "trimmed_mean", "median", "fltrust"])
def test_sharded_matches_scan_engine(method, shared_data):
    """All six methods: byte/cost accounting exact, reputation and final
    accuracy within the documented tolerance."""
    _assert_parity(*_pair(_fl(), method, shared_data))


@pytest.mark.slow
@pytest.mark.parametrize("compressor", ["topk", "qsgd"])
@pytest.mark.parametrize("link_policy", ["cross_only", "all"])
def test_sharded_matches_scan_engine_compressed(compressor, link_policy,
                                                shared_data):
    """EF residuals live sharded with their clients and replay the scan
    engine's state bookkeeping; qsgd's per-sender rounding noise
    (fold_in(client_id)) is engine-invariant, so it holds parity too."""
    fl = _fl(compressor=compressor, compress_ratio=0.25,
             link_policy=link_policy)
    _assert_parity(*_pair(fl, "cost_trustfl", shared_data))


@pytest.mark.slow
@pytest.mark.parametrize("scenario", ["dropout", "price_surge",
                                      "intermittent"])
def test_sharded_matches_scan_engine_scenarios(scenario, shared_data):
    """JitHooks are shard-safe: pure data (dropout p, malice warmup,
    price schedules) consumed identically inside the shard_map'd scan."""
    _assert_parity(*_pair(_fl(), "cost_trustfl", shared_data,
                          scenario=scenario))


@pytest.mark.slow
@pytest.mark.parametrize("attack", ["scaling", "alie", "alie_norm", "ipm",
                                    "collusion"])
def test_sharded_matches_scan_engine_attacks(attack, shared_data):
    """Shard-decomposable adversaries: per-row transforms and masked
    global-moment attacks see the same row set as the scan engine."""
    _assert_parity(*_pair(_fl(attack=attack), "cost_trustfl", shared_data))


@pytest.mark.slow
def test_sharded_matches_scan_engine_multi_features(shared_data):
    """trust_features="multi": the feature pass and the separability-EMA
    gate decompose into per-shard sums + one psum — reputation must
    track the scan engine within the documented tolerance."""
    fl = _fl(trust_features="multi")
    _assert_parity(*_pair(fl, "cost_trustfl", shared_data))


@pytest.mark.slow
def test_server_shard_driver_matches_jit_driver(shared_data):
    """FLServer engine="shard" (per-round step dispatch) tracks the jit
    per-round driver: identical masks and $, reputation to tolerance."""
    fl = _fl()
    topo = make_topology(fl)
    a = FLServer(fl, topo, shared_data, method="cost_trustfl", seed=0,
                 engine="jit")
    b = FLServer(fl, topo, shared_data, method="cost_trustfl", seed=0,
                 engine="shard")
    for t in range(3):
        ma, mb = a.run_round(t), b.run_round(t)
        assert np.array_equal(ma.selected, mb.selected)
        assert ma.cost == mb.cost
        assert ma.extra == mb.extra
    np.testing.assert_allclose(np.array(a.rep.ema), np.array(b.rep.ema),
                               **REP_TOL)


@pytest.mark.slow
def test_sharded_rerun_is_bit_identical(shared_data):
    """Same (config, seed) ⇒ the same sharded SimResult, bit for bit —
    the sharded engine joins the determinism contract."""
    kw = dict(method="cost_trustfl", rounds=3, data=shared_data, seed=0,
              n_devices=1)
    a = run_simulation_sharded(_fl(), **kw)
    b = run_simulation_sharded(_fl(), **kw)
    assert a.accuracy == b.accuracy
    assert a.total_cost == b.total_cost
    assert np.array_equal(a.reputation, b.reputation)


def test_sharded_zero_rounds(shared_data):
    res = run_simulation_sharded(_fl(), method="cost_trustfl", rounds=0,
                                 data=shared_data, seed=0, n_devices=1)
    assert res.final_accuracy is None
    assert res.total_cost == 0.0

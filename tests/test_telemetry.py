"""Telemetry contract tests.

Three guarantees, in test order:

1. **Zero ops when off** — an engine built with the tap disabled lowers
   to HLO *string-identical* to a build that never heard of telemetry,
   so turning the feature off costs literally nothing.
2. **One schema, three engines** — the per-round JSONL emitted by the
   per-round engine-backed ``FLServer`` driver and the single-seed
   ``lax.scan`` live stream is byte-identical; the mesh-sharded engine
   matches exactly on masks/bytes/$ and to 1e-4 on float digests; the
   legacy host loop emits the same (schema-valid) records.
3. **Sinks and reports hold up** — ring buffer is bounded, JSONL
   flushes per event and survives an exception mid-run, the validator
   catches malformed events, and the cost-report table is reproduced
   from events alone.
"""
import json
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "examples"))

from repro.configs.base import FLConfig
from repro.core import CloudTopology, CostModel
from repro.federated import (FLServer, make_data, make_topology,
                             run_simulation, run_simulation_batch,
                             run_simulation_sharded)
from repro.federated import engine as engine_mod
from repro.telemetry import (ListSink, JsonlSink, RingBufferSink, TapSpec,
                             Telemetry, encode, validate_event,
                             validate_events)
from repro.telemetry import report
from repro.telemetry.schema import RunContext

_FL = dict(n_clouds=3, clients_per_cloud=4, clients_per_round=6,
           local_epochs=1, local_batch=8, ref_samples=16,
           attack="sign_flip", malicious_frac=0.3, attack_scale=1.0)


def _parity_setup():
    fl = FLConfig(**_FL)
    data = make_data(fl, "cifar10", seed=0, n_samples=600,
                     samples_per_client=16)
    return fl, data


def _events(fn):
    """Run ``fn(telemetry)`` and return the captured event list."""
    sink = ListSink()
    with Telemetry(sink) as tel:
        fn(tel)
    return sink.events


def _rounds(events):
    return [e for e in events if e["event"] == "round"]


# ---------------------------------------------------------------------------
# 1. zero ops when the tap is off


def test_disabled_tap_lowers_to_identical_hlo():
    """compiled(static, TapSpec(enabled=False)) IS compiled(static) —
    a disabled tap normalizes to the untapped cache entry, so disabled
    telemetry adds ZERO ops by construction: same executable, same
    lowered HLO, not merely a cheap no-op callback."""
    fl, data = _parity_setup()
    topo = make_topology(fl)
    static = engine_mod.static_from(fl, topo, "cost_trustfl",
                                    input_shape=data.client_x.shape[2:],
                                    n_classes=data.n_classes)
    absent = engine_mod.compiled(static)
    off = engine_mod.compiled(static, TapSpec(enabled=False))
    assert off is absent
    dev = engine_mod.make_client_data(fl, topo, data, 0)
    st = absent.init_state(0)
    txt_absent = absent.step.lower(st, dev, 0).as_text()
    assert off.step.lower(st, dev, 0).as_text() == txt_absent

    # and the enabled tap is a genuinely different build: same round
    # math plus the ordered host callback (a custom_call in the HLO)
    on = engine_mod.compiled(static, TapSpec(enabled=True))
    assert on is not absent
    assert on.step.lower(st, dev, 0).as_text() != txt_absent


# ---------------------------------------------------------------------------
# 2. one schema, three engines

@pytest.mark.slow
def test_round_events_byte_identical_server_vs_scan_stream():
    """The per-round engine driver (FLServer engine="jit") and the
    single-seed scan live stream emit byte-identical round JSONL."""
    fl, data = _parity_setup()
    ev_server = _events(lambda tel: run_simulation(
        fl, rounds=4, eval_every=10, data=data, seed=0, engine="jit",
        telemetry=tel))
    ev_stream = _events(lambda tel: run_simulation_batch(
        fl, seeds=[0], rounds=4, data=data, telemetry=tel))
    assert validate_events(ev_server) == []
    assert validate_events(ev_stream) == []
    a = [encode(e) for e in _rounds(ev_server)]
    b = [encode(e) for e in _rounds(ev_stream)]
    assert len(a) == 4
    assert a == b
    # the stream arrives live and in scan order
    assert [e["t"] for e in _rounds(ev_stream)] == [0, 1, 2, 3]


@pytest.mark.slow
def test_multi_seed_replay_matches_stream():
    """Vmapped batches replay events post-run; for the same seed the
    replayed records are byte-identical to the live stream's."""
    fl, data = _parity_setup()
    ev_multi = _events(lambda tel: run_simulation_batch(
        fl, seeds=[0, 1], rounds=3, data=data, telemetry=tel))
    ev_single = _events(lambda tel: run_simulation_batch(
        fl, seeds=[0], rounds=3, data=data, telemetry=tel))
    assert validate_events(ev_multi) == []
    a = [encode(e) for e in _rounds(ev_multi) if e["seed"] == 0]
    b = [encode(e) for e in _rounds(ev_single)]
    assert a == b
    # both seeds emitted a full run: start/rounds/eval/end each
    for s in (0, 1):
        kinds = [e["event"] for e in ev_multi if e.get("seed") == s
                 or e.get("run_id", "").endswith(f"s{s}")]
        assert kinds.count("run_start") == 1
        assert kinds.count("run_end") == 1


@pytest.mark.slow
def test_sharded_engine_digests_match_scan():
    """Sharded round events: masks/bytes/$ byte-exact vs the scan
    stream, float digests within the documented 1e-4."""
    fl, data = _parity_setup()
    ev_scan = _events(lambda tel: run_simulation_batch(
        fl, seeds=[0], rounds=3, data=data, telemetry=tel))
    ev_shard = _events(lambda tel: run_simulation_sharded(
        fl, rounds=3, data=data, seed=0, n_devices=1, telemetry=tel))
    assert validate_events(ev_shard) == []
    ra, rb = _rounds(ev_scan), _rounds(ev_shard)
    assert len(ra) == len(rb) == 3
    for a, b in zip(ra, rb):
        assert b["engine"] == "shard"
        for k in ("t", "n_selected", "n_delivered", "n_active_malicious",
                  "intra_bytes", "cross_bytes", "cost", "cum_cost",
                  "price_mult"):
            assert a[k] == b[k], k
        assert a["digest"]["delivered_sha"] == b["digest"]["delivered_sha"]
        for k in ("params_l2", "rep_l2", "rep_sum"):
            assert b["digest"][k] == pytest.approx(a["digest"][k],
                                                   rel=1e-4, abs=1e-4)


@pytest.mark.slow
def test_host_loop_emits_schema_valid_events():
    """The legacy host loop (different RNG path — schema parity only)
    emits valid events whose totals agree with the server state."""
    fl, data = _parity_setup()
    sink = ListSink()
    with Telemetry(sink) as tel:
        topo = make_topology(fl)
        server = FLServer(fl, topo, data, method="cost_trustfl", seed=0,
                          engine="host", telemetry=tel)
        for t in range(3):
            server.run_round(t)
        server.finish_telemetry()
    assert validate_events(sink.events) == []
    assert {e["engine"] for e in sink.events} == {"host"}
    end = [e for e in sink.events if e["event"] == "run_end"][0]
    assert end["cum_cost"] == pytest.approx(server.cum_cost)
    assert end["rounds_emitted"] == 3
    # host-side spans wrap every round (compile first, execute after)
    spans = [e for e in sink.events if e["event"] == "span"]
    assert [s["phase"] for s in spans][:2] == ["compile+execute", "execute"]


@pytest.mark.slow
def test_tap_overhead_within_budget():
    """The live tap (callback + event build + sink) must not cripple
    the scan engine. The bench reports the honest overhead number
    (telemetry_overhead_pct, acceptance <= 5% steady-state); this CI
    budget is deliberately loose to absorb runner noise."""
    import time

    fl, data = _parity_setup()
    run = lambda tel: run_simulation_batch(fl, seeds=[0], rounds=6,
                                           data=data, telemetry=tel)
    run(None)                         # compile untapped
    _events(run)                      # compile tapped
    t0 = time.perf_counter()
    run(None)
    untapped = time.perf_counter() - t0
    t0 = time.perf_counter()
    _events(run)
    tapped = time.perf_counter() - t0
    assert tapped < 5 * untapped + 0.5


# ---------------------------------------------------------------------------
# 3. sinks, schema validation, reports


def _round_event(**over):
    topo = CloudTopology.even(2, 2)
    ctx = RunContext(None, engine="jit", run_id="r", method="m", attack="a",
                     seed=0, topo=topo, d_params=10, hierarchical=True,
                     m_selected=4, malicious=np.zeros(4, bool))
    ev = ctx.round(0, np.ones(4, bool), np.full(4, 0.5), 1.0)
    ev.update(over)
    return ev


def test_validator_accepts_good_and_rejects_bad_events():
    assert validate_event(_round_event()) == []
    assert validate_event(_round_event(t="zero"))          # wrong type
    assert validate_event(_round_event(engine="tpu"))      # unknown engine
    assert validate_event(_round_event(cost=True))         # bool is not num
    bad = _round_event()
    del bad["digest"]
    assert validate_event(bad)
    bad = _round_event()
    del bad["digest"]["delivered_sha"]
    assert validate_event(bad)
    assert validate_event({"schema": "nope", "event": "round"})
    assert validate_event([1, 2])
    errs = validate_events([_round_event(), _round_event(t=None)])
    assert errs and errs[0].startswith("#1:")


def test_validator_covers_v11_feature_fields():
    """v1.1: ``trust_features`` / ``feat_weights`` are nullable round
    fields — absent, null, or well-typed all pass; wrong types fail."""
    assert validate_event(_round_event(trust_features=None,
                                       feat_weights=None)) == []
    assert validate_event(_round_event(trust_features="multi",
                                       feat_weights=[0.25] * 4)) == []
    assert validate_event(_round_event(trust_features=7))     # not a str
    assert validate_event(_round_event(feat_weights="0.25"))  # not a list
    assert validate_event(_round_event(feat_weights=[0.5, True]))
    assert validate_event(_round_event(feat_weights=[0.5, "x"]))


def test_round_events_carry_feature_weights_on_multi_runs():
    """trust_features="multi" streams the per-round softmax mixing
    weights; the scalar path emits nulls — same schema either way."""
    fl, data = _parity_setup()
    ev_multi = _events(lambda tel: run_simulation_batch(
        FLConfig(**_FL, trust_features="multi"), seeds=[0], rounds=3,
        data=data, telemetry=tel))
    ev_scalar = _events(lambda tel: run_simulation_batch(
        fl, seeds=[0], rounds=3, data=data, telemetry=tel))
    assert validate_events(ev_multi) == []
    for e in _rounds(ev_multi):
        assert e["trust_features"] == "multi"
        w = e["feat_weights"]
        assert isinstance(w, list) and len(w) == 4
        assert all(isinstance(x, float) for x in w)
        assert sum(w) == pytest.approx(1.0, abs=1e-5)
    for e in _rounds(ev_scalar):
        assert e["trust_features"] == "scalar"
        assert e["feat_weights"] is None


def test_ring_buffer_is_bounded():
    sink = RingBufferSink(capacity=3)
    for i in range(10):
        sink.emit({"i": i})
    assert sink.capacity == 3
    assert [e["i"] for e in sink.events] == [7, 8, 9]
    with pytest.raises(ValueError):
        RingBufferSink(capacity=0)


def test_jsonl_sink_flushes_per_event_and_survives_exception(tmp_path):
    path = tmp_path / "events.jsonl"
    with pytest.raises(RuntimeError):
        with Telemetry(JsonlSink(path)) as tel:
            tel.emit({"schema": "s", "event": "x", "i": 0})
            tel.emit({"schema": "s", "event": "x", "i": 1})
            raise RuntimeError("mid-run crash")
    lines = path.read_text().splitlines()
    assert [json.loads(l)["i"] for l in lines] == [0, 1]

    sink = JsonlSink(tmp_path / "b.jsonl")
    sink.emit({"a": 1})
    sink.close()
    sink.close()                       # idempotent
    with pytest.raises(ValueError):
        sink.emit({"a": 2})


def test_telemetry_close_closes_all_sinks_despite_errors():
    class Boom:
        closed = False

        def emit(self, ev):
            pass

        def close(self):
            self.closed = True
            raise OSError("disk gone")

    a, b = Boom(), Boom()
    tel = Telemetry(a, b)
    with pytest.raises(OSError):
        tel.close()
    assert a.closed and b.closed


def test_report_roundtrip_and_validate_only(tmp_path, capsys):
    path = tmp_path / "ev.jsonl"
    with Telemetry(JsonlSink(path)) as tel:
        topo = CloudTopology.even(2, 2)
        ctx = RunContext(tel, engine="jit", run_id="demo", method="m",
                         attack="a", seed=0, topo=topo, d_params=100,
                         hierarchical=True, m_selected=4,
                         malicious=np.zeros(4, bool))
        ctx.run_start(rounds=2)
        for t in range(2):
            ctx.round(t, np.ones(4, bool), np.full(4, 0.5), 1.0)
        ctx.run_end()
    events = report.load_events(path)
    assert validate_events(events) == []
    assert report.main([str(path), "--validate-only"]) == 0
    assert report.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "demo" in out and "cum_cost" in out

    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"schema":"nope","event":"round"}\n')
    assert report.main([str(bad), "--validate-only"]) == 1
    notjson = tmp_path / "nj.jsonl"
    notjson.write_text("{oops\n")
    with pytest.raises(ValueError, match="nj.jsonl:1"):
        report.load_events(notjson)


def test_cost_report_table_agrees_with_cost_model():
    """The example's FL wire breakdown is built from telemetry events
    alone; assert the event-derived numbers equal a direct CostModel
    computation for every policy."""
    import cost_report

    from repro.compress import build_link_policy

    n_clouds, cpc, d = 3, 5, 20_000
    events = cost_report.fl_policy_events(n_clouds, cpc, d)
    assert validate_events(events) == []
    rows = report.wire_breakdown(events)
    assert [r["label"] for r in rows] == [p[0] for p in cost_report.POLICIES]

    topo = CloudTopology.even(n_clouds, cpc)
    cm = CostModel()
    sel = np.ones(topo.n_clients, bool)
    for row, (name, kind, kw) in zip(rows, cost_report.POLICIES):
        lp = build_link_policy(kind, **kw)
        client, edge = lp.payload_vectors(topo, d)
        b = cm.bytes_per_round(topo, sel, d, client_payload=client,
                               edge_payload=edge)
        dollars = cm.round_cost(topo, sel, d, client_payload=client,
                                edge_payload=edge)
        assert row["intra_bytes"] == pytest.approx(float(b["intra"]))
        assert row["cross_bytes"] == pytest.approx(float(b["cross"]))
        assert row["cost"] == pytest.approx(float(dollars))

    table = cost_report.fl_breakdown(n_clouds, cpc, d)
    assert "policy" in table and "fp32 / none" in table

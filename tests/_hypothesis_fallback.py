"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The container that runs the tier-1 suite has no ``hypothesis`` wheel, so
property-test modules import ``given/settings/st`` from here instead.
When the real library is available it is re-exported unchanged; otherwise
a minimal shim runs each ``@given`` test on ``max_examples`` examples
drawn from a seeded generator (seed = hash of the test name), so results
are reproducible run-to-run and machine-to-machine.

Only the strategy surface the suite uses is implemented:
``st.integers``, ``st.floats``, ``st.sampled_from``.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def sample(self, rng):
            return self._draw(rng)

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            pool = list(elements)
            return _Strategy(lambda rng: pool[int(rng.integers(len(pool)))])

    class settings:  # noqa: N801
        _profiles: dict = {}
        _active: dict = {"max_examples": 20}

        def __init__(self, **kwargs):
            self._kwargs = kwargs

        def __call__(self, fn):
            # per-test override, mirroring real hypothesis' @settings
            # decorator semantics: the wrapper (or the bare test) carries
            # its own max_examples, read by ``given`` at call time
            fn._hf_settings = dict(self._kwargs)
            return fn

        @classmethod
        def register_profile(cls, name, max_examples=20, **_ignored):
            cls._profiles[name] = {"max_examples": max_examples}

        @classmethod
        def load_profile(cls, name):
            cls._active = cls._profiles.get(name, cls._active)

    def given(**strategies):
        def decorate(fn):
            # NB: deliberately not functools.wraps — pytest must see a
            # zero-argument signature, or it treats the strategy params
            # as fixtures.
            def wrapper():
                seed = zlib.adler32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                over = (getattr(wrapper, "_hf_settings", None)
                        or getattr(fn, "_hf_settings", None) or {})
                n = over.get("max_examples",
                             settings._active["max_examples"])
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(**drawn)
            for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
                setattr(wrapper, attr, getattr(fn, attr))
            return wrapper
        return decorate

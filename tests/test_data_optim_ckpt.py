"""Substrates: Dirichlet partitioning, synthetic data, optimizers,
checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.fl_types import CloudTopology
from repro.data import (build_federated, dirichlet_partition, iid_partition,
                        make_cifar10_like, make_femnist_like,
                        make_token_stream, token_batches)
from repro.optim import adamw, clip_by_global_norm, cosine_schedule, sgd


# --- data --------------------------------------------------------------------

def test_dirichlet_partition_covers_all_and_skews():
    labels = np.repeat(np.arange(10), 100)
    parts = dirichlet_partition(labels, 20, alpha=0.1, seed=0)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(set(all_idx.tolist())) == 1000
    # low alpha -> clients should be class-skewed vs the global histogram
    ent = []
    for p in parts:
        h = np.bincount(labels[p], minlength=10) / len(p)
        ent.append(-(h[h > 0] * np.log(h[h > 0])).sum())
    assert np.mean(ent) < 0.8 * np.log(10)


def test_dirichlet_more_uniform_at_high_alpha():
    labels = np.repeat(np.arange(10), 200)
    lo = dirichlet_partition(labels, 10, alpha=0.1, seed=1)
    hi = dirichlet_partition(labels, 10, alpha=100.0, seed=1)

    def mean_entropy(parts):
        es = []
        for p in parts:
            h = np.bincount(labels[p], minlength=10) / len(p)
            es.append(-(h[h > 0] * np.log(h[h > 0])).sum())
        return np.mean(es)
    assert mean_entropy(hi) > mean_entropy(lo)


def test_synthetic_datasets_learnable_shapes():
    ds = make_cifar10_like(500, seed=0)
    assert ds.x.shape == (500, 32, 32, 3) and ds.n_classes == 10
    ds2 = make_femnist_like(400, seed=0)
    assert ds2.x.shape == (400, 28, 28, 1) and ds2.n_classes == 62
    assert 0 <= ds.x.min() and ds.x.max() <= 1.0


def test_build_federated_structure():
    topo = CloudTopology.even(3, 4)
    ds = make_cifar10_like(2000, seed=0)
    fd = build_federated(ds, topo, alpha=0.5, samples_per_client=32,
                         ref_samples=20)
    assert fd.client_x.shape == (12, 32, 32, 32, 3)
    assert fd.ref_x.shape == (3, 20, 32, 32, 3)
    assert len(fd.test_x) > 0


def test_token_stream_batches():
    stream = make_token_stream(5000, vocab=512, seed=0)
    it = token_batches(stream, batch=4, seq=16, seed=0)
    b = next(it)
    assert b.shape == (4, 17) and b.max() < 512


# --- optim -------------------------------------------------------------------

def _quad_problem():
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    grad_fn = jax.grad(lambda p: jnp.sum(p["w"] ** 2))
    return params, grad_fn


@pytest.mark.parametrize("opt_fn", [lambda: sgd(0.1),
                                    lambda: sgd(0.1, momentum=0.9),
                                    lambda: adamw(0.1)])
def test_optimizers_descend(opt_fn):
    init, update = opt_fn()
    params, grad_fn = _quad_problem()
    state = init(params)
    for _ in range(80):
        g = grad_fn(params)
        params, state = update(g, state, params)
    assert float(jnp.sum(params["w"] ** 2)) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(norm), 5.0)
    total = jnp.sqrt(clipped["a"] ** 2 + clipped["b"] ** 2)
    assert np.isclose(float(total[0]), 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, warmup=10, total=100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert np.isclose(float(s(jnp.asarray(10))), 1.0)
    assert float(s(jnp.asarray(100))) < 0.2


# --- checkpoint ----------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"layer": {"w": jnp.arange(6.0).reshape(2, 3),
                      "b": jnp.zeros(3)},
            "scanned": [jnp.ones((2, 4))]}
    save_checkpoint(str(tmp_path / "ck"), tree, step=7,
                    metadata={"arch": "test"})
    restored, meta = restore_checkpoint(str(tmp_path / "ck"), tree)
    assert meta["step"] == 7 and meta["arch"] == "test"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"w": jnp.ones((2, 2))}
    save_checkpoint(str(tmp_path / "ck"), tree)
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path / "ck"), {"w": jnp.ones((3, 2))})

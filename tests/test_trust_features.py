"""Multi-feature trust scoring (repro.core.features) and its fused
Pallas pass (repro.kernels.trust_features).

Three layers of guarantee:

* kernel ≡ oracle — the one-pass Pallas feature kernel matches the
  pure-jnp oracle the engines trace, over a hypothesis sweep plus the
  degenerate shapes (single row, empty selection, NaN median);
* gate semantics — with zero separability evidence the gate is exactly
  1 (multi degrades to the scalar Eq. 7 path instead of injecting
  noise), anti-correlated (captured) features earn zero weight, and the
  gate is monotone in the feature scores;
* the AUC gate — on every registry scenario with active malice, the
  multi path's honest-vs-malicious reputation AUC is at least the
  scalar path's. This is the CI contract for the feature: adaptive
  weighting may only ever help.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.configs.base import FLConfig
from repro.core import features as F
from repro.federated import make_data, run_simulation_batch
from repro.kernels import ops, ref
from repro.scenarios import get_scenario, list_scenarios

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _case(m, d, seed, mask_frac=0.3, norm_spread=True):
    rng = np.random.default_rng(seed)
    scale = rng.choice([0.01, 1.0, 50.0], size=(m, 1)) if norm_spread else 1.0
    g = (rng.normal(size=(m, d)) * scale).astype(np.float32)
    r = rng.normal(size=(m, d)).astype(np.float32)
    w = (rng.random(m) >= mask_frac).astype(np.float32)
    gbar = (w @ g) / max(w.sum(), 1.0)
    norms = np.linalg.norm(g, axis=1)
    med = (np.nanmedian(np.where(w > 0, norms, np.nan)) if w.sum()
           else np.float32("nan"))
    return (jnp.asarray(g), jnp.asarray(r), jnp.asarray(gbar),
            jnp.asarray(np.float32(med)), jnp.asarray(w))


# -- kernel vs jnp oracle -----------------------------------------------------

@given(m=st.integers(1, 18), d=st.integers(1, 640), seed=st.integers(0, 5))
def test_kernel_matches_oracle(m, d, seed):
    args = _case(m, d, seed)
    kern = ops.trust_features(*args)
    orac = ref.trust_features_ref(*args)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(orac),
                               rtol=1e-5, atol=1e-5)


def test_kernel_single_row():
    args = _case(1, 37, seed=7, mask_frac=0.0)
    np.testing.assert_allclose(np.asarray(ops.trust_features(*args)),
                               np.asarray(ref.trust_features_ref(*args)),
                               rtol=1e-5, atol=1e-5)


def test_kernel_all_masked_selection():
    """Empty selection ⇒ NaN median; both sides sanitize it to 1 and
    zero out every (undelivered) row — no NaNs may escape."""
    args = _case(6, 50, seed=3, mask_frac=1.1)
    kern = np.asarray(ops.trust_features(*args))
    orac = np.asarray(ref.trust_features_ref(*args))
    assert np.all(np.isfinite(kern)) and np.array_equal(kern, np.zeros_like(kern))
    np.testing.assert_allclose(kern, orac, rtol=1e-5, atol=1e-5)


def test_features_bounded_and_masked():
    g, r, gbar, med, w = _case(12, 100, seed=1)
    feats = np.asarray(F.client_features(g, r, gbar, med, w))
    assert feats.shape == (12, F.N_FEATURES)
    assert np.all(feats >= 0.0) and np.all(feats <= 1.0)
    assert np.array_equal(feats[np.asarray(w) == 0], 0.0 * feats[np.asarray(w) == 0])


def test_loss_delta_is_symmetric_in_norm():
    """f3's norm factor must decay for inflated AND vanishing updates —
    a one-sided clip hands every norm-inflator the maximal factor."""
    d = 64
    direction = np.ones((1, d), np.float32) / np.sqrt(d)
    g = jnp.asarray(np.concatenate([10.0 * direction, direction,
                                    0.1 * direction]))
    r = jnp.asarray(np.repeat(direction, 3, axis=0))
    w = jnp.ones(3)
    feats = np.asarray(F.client_features(g, r, g[1], jnp.asarray(1.0), w))
    f3 = feats[:, 3]
    assert f3[1] > f3[0] and f3[1] > f3[2]
    np.testing.assert_allclose(f3[0], f3[2], rtol=1e-5)


# -- gate semantics -----------------------------------------------------------

def test_gate_is_identity_without_evidence():
    """Zero separability EMA ⇒ β = 0 ⇒ gate ≡ 1: phi_multi degrades to
    the scalar path exactly."""
    feats = jnp.asarray(np.random.default_rng(0).random((9, F.N_FEATURES)),
                        jnp.float32)
    gate = np.asarray(F.gate(feats, jnp.zeros(F.N_FEATURES)))
    np.testing.assert_allclose(gate, np.ones(9), rtol=0, atol=1e-7)


def test_gate_strength_needs_norm_modality():
    """β derives ONLY from the norm profile's separability — direction
    features corroborating the direction anchor is not independent
    evidence (a pure-scaling adversary preserves direction exactly)."""
    sep = np.zeros(F.N_FEATURES, np.float32)
    sep[1] = sep[2] = sep[3] = 1.0          # direction features maxed
    assert float(F.gate_strength(jnp.asarray(sep))) == 0.0
    sep[F.CONSENSUS_FEATURE] = 1.0
    assert float(F.gate_strength(jnp.asarray(sep))) == pytest.approx(F.BETA_MAX)


def test_gate_monotone_in_features():
    """With evidence, a row scoring higher on every feature gets a
    gate at least as large — the gate can demote, never invert."""
    sep = jnp.full((F.N_FEATURES,), 0.8)
    lo = jnp.asarray([[0.1, 0.1, 0.1, 0.1]], jnp.float32)
    hi = jnp.asarray([[0.9, 0.9, 0.9, 0.9]], jnp.float32)
    assert float(F.gate(hi, sep)[0]) > float(F.gate(lo, sep)[0])
    assert float(F.gate(lo, sep)[0]) >= 1.0 - F.BETA_MAX - 1e-6


def test_captured_feature_earns_zero_weight():
    """A feature ANTI-correlated with the reference anchor (the
    signature of a captured signal) must get separability 0, not
    |corr| — this is what makes the weighting adversarially safe."""
    m = 32
    rng = np.random.default_rng(4)
    anchor = rng.random(m).astype(np.float32)
    feats = np.zeros((m, F.N_FEATURES), np.float32)
    feats[:, F.ANCHOR_FEATURE] = anchor
    feats[:, 0] = 1.0 - anchor              # perfectly anti-correlated
    feats[:, 2] = anchor                    # perfectly correlated
    feats[:, 3] = rng.random(m)             # noise
    sep = np.asarray(F.separability(jnp.asarray(feats), jnp.ones(m)))
    assert sep[0] == 0.0
    assert sep[2] == pytest.approx(1.0, abs=1e-5)
    assert sep[F.ANCHOR_FEATURE] == pytest.approx(1.0, abs=1e-5)


def test_separability_sums_decompose():
    """The (6, F) sufficient statistics add across row shards — the
    exactness the sharded engine's single psum relies on."""
    g, r, gbar, med, w = _case(10, 80, seed=2)
    feats = F.client_features(g, r, gbar, med, w)
    whole = F.separability_sums(feats, w)
    parts = (F.separability_sums(feats[:4], w[:4]) +
             F.separability_sums(feats[4:], w[4:]))
    np.testing.assert_allclose(np.asarray(whole), np.asarray(parts),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(F.separability_from_sums(parts)),
        np.asarray(F.separability(feats, w)), rtol=1e-5, atol=1e-5)


def test_feature_weights_uniform_at_zero():
    w = np.asarray(F.feature_weights(jnp.zeros(F.N_FEATURES)))
    np.testing.assert_allclose(w, np.full(F.N_FEATURES, 1.0 / F.N_FEATURES),
                               rtol=1e-6)


# -- the CI AUC gate: multi ≥ scalar on every scenario ------------------------

_GATE_FL = dict(n_clouds=3, clients_per_cloud=4, clients_per_round=6,
                local_epochs=1, local_batch=8, ref_samples=16)
_GATE_ROUNDS = 4
_gate_cache = {}


def _malice_scenarios():
    out = []
    for name in sorted(list_scenarios()):
        ov = get_scenario(name).overrides
        if ov.get("attack", "none") != "none" and ov.get("malicious_frac", 0):
            out.append(name)
    return out


def _auc(rep, mal):
    h, m = rep[~mal], rep[mal]
    diff = h[:, None] - m[None, :]
    return float((diff > 0).mean() + 0.5 * (diff == 0).mean())


def _gate_auc(scenario_name, trust_features):
    key = (scenario_name, trust_features)
    if key not in _gate_cache:
        if "data" not in _gate_cache:
            _gate_cache["data"] = make_data(
                FLConfig(**_GATE_FL), "cifar10", seed=0, n_samples=600,
                samples_per_client=16)
        fl = FLConfig(**_GATE_FL, trust_features=trust_features)
        r = run_simulation_batch(fl, seeds=[0], method="cost_trustfl",
                                 rounds=_GATE_ROUNDS,
                                 data=_gate_cache["data"],
                                 scenario=get_scenario(scenario_name))[0]
        _gate_cache[key] = _auc(np.asarray(r.reputation),
                                np.asarray(r.malicious))
    return _gate_cache[key]


@pytest.mark.parametrize("scenario", _malice_scenarios())
def test_multi_auc_at_least_scalar(scenario):
    """The adaptive multi-feature gate may never rank honest clients
    below attackers where the scalar Eq. 7 path did not: its confidence
    β scales with accumulated two-modality evidence and is capped, so
    with weak evidence it degrades to the scalar ranking. Exact
    equality is common at this budget — the contract is ≥, on EVERY
    scenario with active malice."""
    scalar = _gate_auc(scenario, "scalar")
    multi = _gate_auc(scenario, "multi")
    assert multi >= scalar - 1e-9, (
        f"{scenario}: multi AUC {multi:.4f} < scalar AUC {scalar:.4f}")
